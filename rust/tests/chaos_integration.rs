//! Chaos-engineered serving integration: seeded fault injection driven
//! end-to-end through the HTTP front end.  Every scenario arms a
//! deterministic [`ChaosPlan`] (the same spec grammar `--chaos-spec`
//! accepts), drives real sockets against it, and asserts the paper's
//! serving invariants hold under fire: digital results stay
//! bit-identical, failures surface as clean statuses instead of hangs,
//! and the breaker + respawn machinery converges back to health.
//!
//! Compiled only with `--features chaos` — the injection points these
//! tests arm do not exist in a default build.
#![cfg(feature = "chaos")]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use repro::bitplane::QuantBwht;
use repro::chaos::ChaosPlan;
use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use repro::nn::{Backend, Mlp};
use repro::server::{Server, ServerConfig};
use repro::util::json::{self, Json};
use repro::util::rng::Rng;

fn send_request(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read response");
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

fn post_json(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    send_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    send_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn transform_body(x: &[f32]) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!("{{\"x\":[{}]}}", xs.join(","))
}

/// Read one framed HTTP response off a persistent connection.
fn read_response(
    reader: &mut BufReader<TcpStream>,
) -> (u16, Vec<(String, String)>, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("header line");
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        let (name, value) = trimmed.split_once(':').expect("header colon");
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_string();
        if name == "content-length" {
            content_length = value.parse().expect("content length");
        }
        headers.push((name, value));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    (status, headers, String::from_utf8(body).expect("utf-8 body"))
}

fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|line| {
            let rest = line.strip_prefix(name)?;
            let rest = rest.strip_prefix(' ')?;
            rest.trim().parse::<f64>().ok()
        })
        .unwrap_or(f64::NAN)
}

fn parse_y(body: &str) -> Vec<f32> {
    json::parse(body)
        .expect("response json")
        .get("y")
        .and_then(Json::as_arr)
        .expect("y array")
        .iter()
        .map(|v| v.as_f64().expect("numeric y") as f32)
        .collect()
}

fn chaos_server(spec: &str, mutate: impl FnOnce(&mut ServerConfig)) -> Server {
    let mut config = ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        coordinator: CoordinatorConfig {
            chaos: ChaosPlan::parse(spec).expect("chaos spec"),
            ..Default::default()
        },
        ..Default::default()
    };
    mutate(&mut config);
    Server::start(config).expect("server start")
}

fn test_mlp() -> Mlp {
    let mut r = Rng::seed_from_u64(77);
    let (din, hidden, classes) = (8usize, 16usize, 3usize);
    Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.5),
        vec![0.0; hidden],
        vec![0.06; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.5),
        vec![0.0; classes],
    )
}

#[test]
fn slowdowns_stalls_and_short_io_leave_transforms_bit_identical() {
    // Degraded-but-alive faults everywhere at once: every pool job is
    // slowed, every socket read and write is truncated to one byte
    // (exercising the level-triggered re-arm paths), and one batch in
    // five stalls the whole pipeline.  Nothing may corrupt a result.
    let server = chaos_server(
        "pool.worker.slow=1.0;conn.short_read=1.0;conn.short_write=1.0;batcher.stall=0.2,3",
        |_| {},
    );
    let addr = server.addr;

    let mut clients = Vec::new();
    for client in 0..4u64 {
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(5000 + client);
            for _ in 0..3 {
                let x: Vec<f32> = (0..16)
                    .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                    .collect();
                let (status, body) = post_json(addr, "/v1/transform", &transform_body(&x));
                assert_eq!(status, 200, "body: {body}");
                assert_eq!(
                    parse_y(&body),
                    QuantBwht::new(16, 16, 8).transform(&x),
                    "slow/short-IO serving must stay bit-identical"
                );
            }
        }));
    }
    for handle in clients {
        handle.join().expect("client thread");
    }

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    let m = server.shutdown();
    assert_eq!(m.requests, 12);
}

#[test]
fn worker_panics_surface_as_clean_500s_not_hangs() {
    // Every pool job panics.  The catch_unwind seam must convert that
    // into a failed batch, the router must exhaust its shards, and the
    // client must see a clean 500 — never a hung connection.
    let server = chaos_server("pool.worker.panic=1.0", |_| {});
    let addr = server.addr;

    let (status, body) = post_json(addr, "/v1/transform", &transform_body(&[0.5; 16]));
    assert_eq!(status, 500, "body: {body}");
    assert!(body.contains("failed"), "{body}");

    // The control plane outlives the data-plane failure.
    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200, "{body}");
    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert!(metrics.contains("repro_shard_breaker_state"), "{metrics}");
    server.shutdown();
}

#[test]
fn shard_kills_under_concurrent_infer_load_stay_bit_identical() {
    // The health tick murders a rotating healthy shard more often than
    // not, sparing only the last one.  Inference must keep returning
    // logits bit-identical to the golden quantized forward, and the
    // respawn machinery must bring killed shards back.
    let mlp = test_mlp();
    let golden_mlp = mlp.clone();
    let server = chaos_server("shard.kill=0.6,11", |c| {
        c.shards = 3;
        c.model = Some(mlp);
        c.auto_respawn = true;
        c.health_tick = Duration::from_millis(20);
    });
    let addr = server.addr;

    let mut clients = Vec::new();
    for client in 0..4u64 {
        let mlp = golden_mlp.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(6000 + client);
            for _ in 0..6 {
                let x: Vec<f32> = (0..8)
                    .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                    .collect();
                let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
                let (status, body) = post_json(
                    addr,
                    "/v1/infer",
                    &format!("{{\"x\":[{}]}}", xs.join(",")),
                );
                assert_eq!(status, 200, "body: {body}");
                let parsed = json::parse(&body).unwrap();
                let logits: Vec<f32> = parsed
                    .get("logits")
                    .and_then(Json::as_arr)
                    .expect("logits")
                    .iter()
                    .map(|v| v.as_f64().expect("number") as f32)
                    .collect();
                let want = mlp.forward(
                    &x,
                    1,
                    Backend::Quantized { bits: 8 },
                    &mut Rng::seed_from_u64(0),
                );
                assert_eq!(logits, want, "failover must preserve bit-identity");
            }
        }));
    }
    for handle in clients {
        handle.join().expect("client thread");
    }

    // The kills really happened and the heal pass brought shards back.
    let give_up = Instant::now() + Duration::from_secs(10);
    let mut respawned = false;
    while Instant::now() < give_up {
        let (_, metrics) = get(addr, "/metrics");
        if metric_value(&metrics, "repro_shard_respawns_total") >= 1.0 {
            assert!(metric_value(&metrics, "repro_shards_healthy") >= 1.0, "{metrics}");
            assert!(metrics.contains("repro_shard_breaker_state{shard=\"0\"}"));
            assert!(metrics.contains("repro_shard_respawn_backoff_seconds{shard=\"0\"}"));
            respawned = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert!(respawned, "chaos kills must flow through the respawn machinery");
    server.shutdown();
}

#[test]
fn flapped_shards_recover_through_half_open_probes_to_full_health() {
    // A flap bounces a shard (kill + immediate respawn), leaving its
    // breaker half-open.  Wide requests span slices across every shard,
    // so probe traffic reaches the bounced one and its breaker must
    // walk half-open -> closed; between flaps the whole set converges
    // back to 3 healthy shards with every breaker closed.
    let server = chaos_server("shard.flap=0.35,5", |c| {
        c.shards = 3;
        c.auto_respawn = true;
        c.health_tick = Duration::from_millis(20);
    });
    let addr = server.addr;

    let mut rng = Rng::seed_from_u64(7000);
    let x: Vec<f32> = (0..200)
        .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let golden = {
        // A chaos-free single pool is the reference; the flapping
        // 3-shard server must match it bit-for-bit.
        let mut single = Coordinator::new(CoordinatorConfig::default());
        let y = single
            .transform(&TransformRequest {
                x: x.clone(),
                thresholds_units: vec![0.0; 200],
                scale: None,
                deadline: None,
            })
            .unwrap();
        single.shutdown();
        y
    };

    // Load phase: every response bit-identical while shards bounce.
    for i in 0..12 {
        let (status, body) = post_json(addr, "/v1/transform", &transform_body(&x));
        assert_eq!(status, 200, "request {i}: {body}");
        assert_eq!(parse_y(&body), golden, "request {i}");
    }

    // Recovery phase: keep probing until a scrape shows full health
    // with every breaker closed (flaps are bounded-rate, so clean
    // windows recur; a breaker stuck open would never satisfy this).
    let give_up = Instant::now() + Duration::from_secs(15);
    let mut recovered = false;
    while Instant::now() < give_up {
        let (status, body) = post_json(addr, "/v1/transform", &transform_body(&x));
        assert_eq!(status, 200, "{body}");
        let (_, metrics) = get(addr, "/metrics");
        let healthy = metric_value(&metrics, "repro_shards_healthy");
        let all_closed = (0..3).all(|s| {
            metric_value(
                &metrics,
                &format!("repro_shard_breaker_state{{shard=\"{s}\"}}"),
            ) == 0.0
        });
        if healthy == 3.0 && all_closed {
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        recovered,
        "flapped shards must recover to closed breakers under probe traffic"
    );
    server.shutdown();
}

#[test]
fn stalled_workers_with_a_tight_deadline_answer_504_and_close() {
    // Every pool job stalls 50ms; the request carries a 5ms end-to-end
    // deadline.  The connection's deadline timer must fire first: a 504
    // with Connection: close (the server cannot know whether the
    // batcher's side effects happened), and the deadline counters tick.
    let server = chaos_server("pool.worker.stall=1.0", |_| {});
    let addr = server.addr;

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let body = transform_body(&[0.5; 16]);
    write!(
        writer,
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nX-Deadline-Ms: 5\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, headers, body) = read_response(&mut reader);
    assert_eq!(status, 504, "{body}");
    assert_eq!(
        header_value(&headers, "connection"),
        Some("close"),
        "an expired request must not reuse the keep-alive stream"
    );
    assert!(body.contains("timed out"), "{body}");
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after the 504");

    let (_, metrics) = get(addr, "/metrics");
    assert!(
        metric_value(&metrics, "repro_requests_deadline_expired_total") >= 1.0,
        "{metrics}"
    );
    assert!(
        metric_value(
            &metrics,
            "repro_requests_dropped_total{reason=\"deadline\"}"
        ) >= 1.0,
        "{metrics}"
    );
    server.shutdown();
}

#[test]
fn dropped_replies_answer_504_close_and_count() {
    // Every batcher reply is dropped before it reaches the connection.
    // The sink's drop guard must surface a prompt 504 (not a hang until
    // the request timeout), close the stream, and count the loss.
    let server = chaos_server("batcher.reply.drop=1.0", |_| {});
    let addr = server.addr;

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let body = transform_body(&[0.25; 16]);
    let started = Instant::now();
    write!(
        writer,
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let (status, headers, body) = read_response(&mut reader);
    assert_eq!(status, 504, "{body}");
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "a dropped reply must fail fast, not wait out the request timeout"
    );
    assert_eq!(header_value(&headers, "connection"), Some("close"));
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());

    let (_, metrics) = get(addr, "/metrics");
    assert!(
        metric_value(
            &metrics,
            "repro_requests_dropped_total{reason=\"reply_dropped\"}"
        ) >= 1.0,
        "{metrics}"
    );
    server.shutdown();
}

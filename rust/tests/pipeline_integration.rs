//! Cross-module integration: nn engine ↔ coordinator ↔ analog simulator.

use repro::analog::crossbar::CrossbarConfig;
use repro::coordinator::{Coordinator, CoordinatorConfig, TileKind, TransformRequest};
use repro::energy::EnergyModel;
use repro::nn::{Backend, BwhtLayer};
use repro::util::prop;
use repro::util::rng::Rng;
use repro::wht;

#[test]
fn coordinator_digital_equals_nn_quantized_backend_per_tile() {
    // A width-16 layer forward via (a) the nn quantized backend and
    // (b) the coordinator tile pool must produce the same frequency-domain
    // transform (single transform pass, T=0).
    let mut rng = Rng::seed_from_u64(1);
    let x: Vec<f32> = (0..16).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let eng = repro::bitplane::QuantBwht::new(16, 16, 8);
    let direct = eng.transform(&x);
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 16,
        ..Default::default()
    });
    let pooled = coord
        .transform(&TransformRequest {
            x: x.clone(),
            thresholds_units: vec![0.0; 16],
            scale: None,
            deadline: None,
        })
        .unwrap();
    assert_eq!(direct, pooled);
    coord.shutdown();
}

#[test]
fn analog_tiles_track_digital_at_nominal_vdd() {
    let x_width = 32;
    let mut rng = Rng::seed_from_u64(2);
    let x: Vec<f32> = (0..x_width)
        .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let run = |kind: TileKind| {
        let mut c = Coordinator::new(CoordinatorConfig {
            tile_n: 16,
            kind,
            workers: 2,
            ..Default::default()
        });
        let out = c
            .transform(&TransformRequest {
                x: x.clone(),
                thresholds_units: vec![0.0; x_width],
                scale: None,
                deadline: None,
            })
            .unwrap();
        c.shutdown();
        out
    };
    let digital = run(TileKind::Digital);
    let analog = run(TileKind::Analog {
        config: CrossbarConfig::new(16, 0.9),
    });
    // Exact value equality across all 8 recombined planes is not expected
    // (near-zero PSUMs flip under comparator noise — that is the ANT
    // regime of Fig. 11a); what must hold at 0.9 V is that the outputs
    // track closely in aggregate (Fig. 11b: >95% bit accuracy outside the
    // safety margin ⇒ high vector correlation).
    let dot: f64 = digital
        .iter()
        .zip(&analog)
        .map(|(a, b)| *a as f64 * *b as f64)
        .sum();
    let na: f64 = digital.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = analog.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
    let cos = dot / (na * nb).max(1e-12);
    // The residual gap is dominated by exactly-balanced PSUMs (digital
    // convention sign(0)=0; a real comparator resolves them ±1 at random),
    // not by process variability.
    assert!(
        cos > 0.85,
        "analog/digital correlation too low at 0.9 V: {cos:.3}"
    );
}

#[test]
fn layer_roundtrip_through_coordinator_tiles() {
    // Full BWHT layer (fwd transform -> S_T -> inverse) where both
    // transforms run on coordinator tiles; compare against the nn
    // Quantized backend which uses the same golden arithmetic.
    let width = 16usize;
    let mut rng = Rng::seed_from_u64(3);
    let x: Vec<f32> = (0..width)
        .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let t = vec![0.1f32; width];
    let layer = BwhtLayer::new(width, width, t.clone(), width);
    let want = layer.forward(
        &x,
        1,
        width,
        width,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );

    // Manual two-pass through the coordinator.
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: width,
        ..Default::default()
    });
    let norm = 1.0f32 / (width as f32).sqrt();
    let f1 = coord
        .transform(&TransformRequest {
            x: x.clone(),
            thresholds_units: vec![0.0; width],
            scale: None,
            deadline: None,
        })
        .unwrap();
    let mut freq: Vec<f32> = f1.iter().map(|v| v * norm).collect();
    // soft threshold
    for (v, th) in freq.iter_mut().zip(&t) {
        let a = v.abs() - th.abs();
        *v = if a > 0.0 { v.signum() * a } else { 0.0 };
    }
    let f2 = coord
        .transform(&TransformRequest {
            x: freq,
            thresholds_units: vec![0.0; width],
            scale: None,
            deadline: None,
        })
        .unwrap();
    let got: Vec<f32> = f2.iter().map(|v| v * norm).collect();
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-5, "elem {i}: {a} vs {b}");
    }
    coord.shutdown();
}

#[test]
fn property_early_termination_never_changes_results() {
    // For ANY input and ANY threshold, ET output == full-run output
    // passed through the |y| <= T zeroing (soundness at system level).
    prop::forall(
        60,
        7,
        |r| {
            let x = prop::vec_f32(r, 16, 2.0);
            let t = r.uniform_range(0.0, 300.0);
            (x, t)
        },
        |(x, t)| {
            let mut c_et = Coordinator::new(CoordinatorConfig {
                tile_n: 16,
                ..Default::default()
            });
            let et = c_et
                .transform(&TransformRequest {
                    x: x.clone(),
                    thresholds_units: vec![*t; 16],
                    scale: None,
                    deadline: None,
                })
                .unwrap();
            c_et.shutdown();
            let mut c_full = Coordinator::new(CoordinatorConfig {
                tile_n: 16,
                ..Default::default()
            });
            let full = c_full
                .transform(&TransformRequest {
                    x: x.clone(),
                    thresholds_units: vec![0.0; 16],
                    scale: None,
                    deadline: None,
                })
                .unwrap();
            c_full.shutdown();
            let q = repro::quant::Quantizer::new(8).quantize(x);
            for i in 0..16 {
                let units = (full[i] / q.scale).round() as i64;
                let want = if (units.unsigned_abs() as f64) <= *t {
                    0.0
                } else {
                    full[i]
                };
                if et[i] != want {
                    return Err(format!("elem {i}: et {} vs want {want}", et[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_transform_linearity_of_exact_path() {
    // The exact (float) blockwise WHT is linear; the quantized path is
    // not, but must stay within the quantization error envelope.
    prop::forall(
        40,
        11,
        |r| prop::vec_f32(r, 32, 1.0),
        |x| {
            let exact = wht::bwht_apply(x, 32, 16);
            let eng = repro::bitplane::QuantBwht::new(32, 16, 8);
            let approx = eng.transform(x);
            // Envelope: every quantized output is bounded by the max
            // possible recombined magnitude.
            let q = eng.quantizer.quantize(x);
            let bound = q.scale * 255.0 + 1e-4;
            for (i, a) in approx.iter().enumerate() {
                if a.abs() > bound {
                    return Err(format!("elem {i} out of envelope: {a} > {bound}"));
                }
            }
            // And the exact path satisfies Parseval-style energy scaling.
            let ex: f32 = x.iter().map(|v| v * v).sum();
            let ef: f32 = exact.iter().map(|v| v * v).sum::<f32>() / 16.0;
            if (ex - ef).abs() > 0.01 * ex.max(1e-3) {
                return Err(format!("Parseval violated: {ex} vs {ef}"));
            }
            Ok(())
        },
    );
}

#[test]
fn serve_et_improves_tops_per_watt() {
    // System-level Table I story: ET-enabled serving beats no-ET on the
    // energy model, because Wald-trained thresholds cut executed cycles.
    let model = EnergyModel::new(16, 0.8);
    let mut rng = Rng::seed_from_u64(5);
    let mk_reqs = |rng: &mut Rng, wald: bool| -> Vec<TransformRequest> {
        (0..64)
            .map(|_| {
                let x: Vec<f32> = (0..16).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
                let th = if wald {
                    (0..16)
                        .map(|_| {
                            repro::bitplane::early_term::sample_threshold(
                                rng,
                                repro::bitplane::early_term::ThresholdDist::Wald,
                                1.0,
                            )
                            .abs()
                                * 255.0
                        })
                        .collect()
                } else {
                    vec![0.0; 16]
                };
                TransformRequest {
                    x,
                    thresholds_units: th,
                    scale: None,
                    deadline: None,
                }
            })
            .collect()
    };
    let mut c1 = Coordinator::new(CoordinatorConfig::default());
    c1.transform_batch(&mk_reqs(&mut rng, true)).unwrap();
    let et = c1.metrics();
    c1.shutdown();
    let mut c2 = Coordinator::new(CoordinatorConfig::default());
    c2.transform_batch(&mk_reqs(&mut rng, false)).unwrap();
    let no_et = c2.metrics();
    c2.shutdown();
    assert!(et.average_cycles() < 2.0, "{}", et.average_cycles());
    assert!(
        et.tops_per_watt(&model) > 2.0 * no_et.tops_per_watt(&model),
        "ET {} vs no-ET {}",
        et.tops_per_watt(&model),
        no_et.tops_per_watt(&model)
    );
}

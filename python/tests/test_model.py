"""Model shapes, modes, parameter accounting, split/merge round-trip."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, walsh


def randx(shape, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(np.float32))


class TestBwhtLayer:
    def test_expansion_shape(self):
        p = model.init_bwht(np.random.RandomState(0), 32)
        x = randx((4, 6, 6, 16))
        y = model.bwht_layer(p, x, 32)
        assert y.shape == (4, 6, 6, 32)

    def test_projection_shape(self):
        p = model.init_bwht(np.random.RandomState(0), 32)
        x = randx((4, 6, 6, 32))
        y = model.bwht_layer(p, x, 8)
        assert y.shape == (4, 6, 6, 8)

    @pytest.mark.parametrize("mode", ["float", "qat", "soft"])
    def test_all_modes_run(self, mode):
        p = model.init_bwht(np.random.RandomState(0), 16)
        x = randx((2, 16))
        y = model.bwht_layer(p, x, 16, mode=mode, bits=4, tau=8.0)
        assert y.shape == (2, 16)
        assert np.isfinite(np.asarray(y)).all()

    def test_parameter_count_is_thresholds_only(self):
        p = model.init_bwht(np.random.RandomState(0), 64)
        assert model.count_params(p) == walsh.bwht_padded_dim(64)


class TestBlocks:
    def test_residual_block_conv_vs_bwht_params(self):
        rng = np.random.RandomState(0)
        p_conv = model.init_residual_block(rng, 32, 32, use_bwht=False)
        p_bwht = model.init_residual_block(rng, 32, 32, use_bwht=True)
        # BWHT block replaces the 32x32 1x1 conv (1024+32 params) with 32 T.
        assert model.count_params(p_bwht) < model.count_params(p_conv)
        diff = model.count_params(p_conv) - model.count_params(p_bwht)
        assert diff == (32 * 32 + 32) - 32

    @pytest.mark.parametrize("use_bwht", [False, True])
    def test_residual_block_shape(self, use_bwht):
        rng = np.random.RandomState(1)
        p = model.init_residual_block(rng, 16, 32, use_bwht=use_bwht)
        y = model.residual_block(p, randx((2, 8, 8, 16)), "float", 8, 8.0)
        assert y.shape == (2, 8, 8, 32)

    @pytest.mark.parametrize("use_bwht", [False, True])
    def test_bottleneck_block_shape(self, use_bwht):
        rng = np.random.RandomState(2)
        p = model.init_bottleneck_block(rng, 16, 4, 16, use_bwht=use_bwht)
        y = model.bottleneck_block(p, randx((2, 8, 8, 16)), "float", 8, 8.0)
        assert y.shape == (2, 8, 8, 16)

    def test_bottleneck_bwht_fewer_params(self):
        rng = np.random.RandomState(3)
        p_conv = model.init_bottleneck_block(rng, 16, 4, 16, use_bwht=False)
        p_bwht = model.init_bottleneck_block(rng, 16, 4, 16, use_bwht=True)
        assert model.count_params(p_bwht) < model.count_params(p_conv)


class TestResnet:
    def test_forward_shape(self):
        p = model.init_bwht_resnet(0, freq_layers=3)
        y = model.bwht_resnet(p, randx((2, 16, 16, 3)))
        assert y.shape == (2, 10)

    def test_param_count_monotone_in_freq_layers(self):
        counts = [
            model.count_params(model.init_bwht_resnet(0, k))
            for k in range(model.num_mixing_layers() + 1)
        ]
        assert counts == sorted(counts, reverse=True), counts
        # Full frequency processing must compress substantially (Fig 1b).
        assert counts[-1] < 0.75 * counts[0]

    @pytest.mark.parametrize("mode", ["float", "qat"])
    def test_modes_finite(self, mode):
        p = model.init_bwht_resnet(1, freq_layers=6)
        y = model.bwht_resnet(p, randx((2, 16, 16, 3)), mode=mode, bits=4)
        assert np.isfinite(np.asarray(y)).all()


class TestMlp:
    def test_shapes(self):
        p = model.init_mlp(0)
        y = model.mlp_forward(p, randx((8, 64)))
        assert y.shape == (8, 10)

    @pytest.mark.parametrize("mode", ["float", "qat", "soft"])
    def test_modes(self, mode):
        p = model.init_mlp(0)
        y = model.mlp_forward(p, randx((4, 64)), mode=mode, bits=4)
        assert np.isfinite(np.asarray(y)).all()


class TestSplitMerge:
    @pytest.mark.parametrize(
        "make",
        [
            lambda: model.init_mlp(0),
            lambda: model.init_bwht_resnet(0, 2),
            lambda: model.init_bottleneck_block(
                np.random.RandomState(0), 8, 2, 8, True
            ),
        ],
    )
    def test_roundtrip(self, make):
        p = make()
        arrs, stat = model.split_params(p)
        p2 = model.merge_params(arrs, stat)

        def compare(a, b):
            if isinstance(a, dict):
                assert set(a) == set(b)
                for k in a:
                    compare(a[k], b[k])
            elif isinstance(a, list):
                assert len(a) == len(b)
                for x, y in zip(a, b):
                    compare(x, y)
            elif hasattr(a, "shape"):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            else:
                assert a == b

        compare(p, p2)

    def test_arrays_tree_has_no_static_leaves(self):
        arrs, _ = model.split_params(model.init_bwht_resnet(0, 3))
        import jax

        for leaf in jax.tree_util.tree_leaves(arrs):
            assert hasattr(leaf, "shape"), f"non-array leaf {leaf!r}"

    def test_collect_thresholds(self):
        p = model.init_bwht_resnet(0, freq_layers=4)
        ts = model.collect_thresholds(p)
        assert len(ts) == 4

"""Properties of the pure-jnp oracle itself (it anchors everything else)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import walsh
from compile.kernels import ref


def randn(shape, seed=0, scale=1.0):
    return jnp.asarray(
        (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)
    )


class TestQuantize:
    def test_roundtrip_error_bounded(self):
        x = randn((100,), 0, scale=3.0)
        for bits in [2, 4, 8]:
            q, scale = ref.quantize_ref(x, bits)
            err = np.abs(np.asarray(q * scale - x))
            assert err.max() <= float(scale) / 2 + 1e-6

    def test_range(self):
        x = randn((64,), 1)
        q, _ = ref.quantize_ref(x, 8)
        assert np.abs(np.asarray(q)).max() <= 255

    def test_extremes_hit_qmax(self):
        x = jnp.asarray([1.0, -1.0, 0.5], jnp.float32)
        q, s = ref.quantize_ref(x, 8)
        assert float(jnp.max(jnp.abs(q))) == 255

    def test_1bit_is_ternary(self):
        x = randn((64,), 2)
        q, _ = ref.quantize_ref(x, 1)
        assert set(np.unique(np.asarray(q))) <= {-1.0, 0.0, 1.0}

    @given(bits=st.integers(1, 8), seed=st.integers(0, 500))
    @settings(max_examples=40, deadline=None)
    def test_q_is_integer(self, bits, seed):
        x = randn((32,), seed)
        q, _ = ref.quantize_ref(x, bits)
        np.testing.assert_allclose(np.asarray(q), np.round(np.asarray(q)))


class TestBitplanes:
    def test_reconstruction(self):
        """sum_b plane_b * 2^b must reconstruct the signed integer."""
        rng = np.random.RandomState(3)
        q = jnp.asarray(rng.randint(-127, 128, size=(5, 7)).astype(np.float32))
        planes = ref.bitplanes_ref(q, 8)
        w = 2.0 ** np.arange(8)
        recon = np.tensordot(w, np.asarray(planes), axes=(0, 0))
        np.testing.assert_allclose(recon, np.asarray(q))

    def test_values_in_pm1(self):
        q = jnp.asarray([[-5.0, 3.0, 0.0]])
        planes = np.asarray(ref.bitplanes_ref(q, 4))
        assert set(np.unique(planes)) <= {-1.0, 0.0, 1.0}

    def test_sign_magnitude_symmetry(self):
        q = jnp.asarray([[37.0]])
        p_pos = np.asarray(ref.bitplanes_ref(q, 8))
        p_neg = np.asarray(ref.bitplanes_ref(-q, 8))
        np.testing.assert_allclose(p_pos, -p_neg)


class TestQuantBwhtConvergence:
    """Eq. 4 must converge to the true transform direction as bits grow."""

    def _cosine(self, a, b):
        a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
        return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))

    def test_sign_agreement_increases_with_bits(self):
        x = randn((32, 64), 7)
        exact = ref.bwht_ref(x)
        cos = [
            self._cosine(jnp.sign(ref.quant_bwht_ref(x, bits=b)), jnp.sign(exact))
            for b in (1, 4, 8)
        ]
        assert cos[-1] > cos[0] - 1e-9
        # Eq. 4 is a *crude* approximation (hence the paper's 3-4% accuracy
        # loss and the need to retrain) — require correlation, not fidelity.
        assert cos[-1] > 0.4, f"8-bit Eq.4 should track transform signs, got {cos}"

    def test_1bit_output_is_pm_scale(self):
        x = randn((4, 16), 8)
        y = ref.quant_bwht_ref(x, bits=1)
        q, scale = ref.quantize_ref(x, 1)
        vals = np.unique(np.round(np.asarray(y / scale), 5))
        assert set(vals) <= {-1.0, 0.0, 1.0}


class TestBwhtLayerRef:
    def test_energy_nonincreasing(self):
        """Soft-thresholding in an orthonormal basis shrinks the norm."""
        x = randn((10, 32), 9, scale=2.0)
        t = jnp.full((32,), 0.4, jnp.float32)
        y = ref.bwht_layer_ref(x, t)
        assert np.linalg.norm(np.asarray(y)) <= np.linalg.norm(np.asarray(x)) + 1e-4

    def test_sparsity_increases_with_t(self):
        x = randn((10, 32), 10)
        w = jnp.asarray(walsh.walsh(5).astype(np.float32)) / np.sqrt(32.0)
        sparsity = []
        for tval in [0.0, 0.3, 1.0]:
            t = jnp.full((32,), tval, jnp.float32)
            freq = (x @ w.T)
            thr = ref.soft_threshold_ref(freq, t)
            sparsity.append(float(jnp.mean(thr == 0.0)))
        assert sparsity[0] <= sparsity[1] <= sparsity[2]
        assert sparsity[2] > 0.5

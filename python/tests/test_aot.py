"""AOT artifact generation: HLO text validity and manifest consistency.

These tests exercise the same code path as `make artifacts` but into a
tmpdir, on the small artifacts only (train_step is covered by the checked-in
artifacts + the rust integration tests).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


class TestHloText:
    def test_wht16_lowers_to_hlo_text(self):
        lowered = jax.jit(aot.wht16).lower(jax.ShapeDtypeStruct((16, 16), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "HloModule" in text

    def test_mlp_fwd_matches_model(self):
        """The artifact function must equal the model's float forward."""
        p = model.init_mlp(0)
        x = jnp.asarray(np.random.RandomState(0).randn(8, 64).astype(np.float32))
        (got,) = aot.mlp_fwd(
            p["fc1"]["w"], p["fc1"]["b"], p["bwht"]["t"],
            p["fc2"]["w"], p["fc2"]["b"], x,
        )
        want = model.mlp_forward(p, x, mode="float")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_train_step_reduces_loss(self):
        """Iterating the artifact's train_step must reduce its loss output."""
        p = model.init_mlp(0)
        rng = np.random.RandomState(1)
        x = jnp.asarray(rng.randn(64, 64).astype(np.float32))
        y = jnp.asarray(rng.randint(0, 10, 64).astype(np.int32))
        flat = [
            p["fc1"]["w"], p["fc1"]["b"], p["bwht"]["t"],
            p["fc2"]["w"], p["fc2"]["b"],
        ]
        losses_seen = []
        step = jax.jit(aot.train_step)
        for _ in range(12):
            *flat, loss = step(*flat, x, y)
            losses_seen.append(float(loss))
        assert losses_seen[-1] < losses_seen[0], losses_seen

    def test_quant_artifact_matches_ref(self):
        x = jnp.asarray(np.random.RandomState(2).randn(32, 64).astype(np.float32))
        (got,) = aot.quant_bwht64(x)
        want = ref.quant_bwht_ref(x, bits=aot.BITS_AOT)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestNoElidedConstants:
    def test_large_constants_are_printed(self):
        """Regression: default as_hlo_text() elides the baked Walsh
        matrices as literal "{...}", which the rust text parser silently
        reads back as ZEROS (the E2E model then trains to a flat loss).
        """
        lowered = jax.jit(aot.quant_bwht64).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert "constant({...})" not in text
        # the 64-wide Walsh block must appear as a real f32 literal
        assert "f32[64,64]" in text


class TestManifest:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = str(tmp_path_factory.mktemp("artifacts"))
        manifest = aot.build_artifacts(out, batch=64)
        return out, manifest

    def test_all_files_exist(self, built):
        out, manifest = built
        for name, meta in manifest["artifacts"].items():
            path = os.path.join(out, meta["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_manifest_json_parses(self, built):
        out, _ = built
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert m["bits"] == aot.BITS_AOT
        ts = m["artifacts"]["train_step"]["args"]
        assert [a["name"] for a in ts] == [
            "fc1_w", "fc1_b", "bwht_t", "fc2_w", "fc2_b", "x", "y",
        ]
        assert ts[-1]["dtype"] == "int32"

    def test_arg_shapes_recorded(self, built):
        _, manifest = built
        args = {a["name"]: a for a in manifest["artifacts"]["mlp_fwd"]["args"]}
        assert args["x"]["shape"] == [64, 64]
        assert args["fc2_w"]["shape"] == [64, 10]

"""Training loop: optimizer correctness and learning signal in every mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, train


class TestAdam:
    def test_step_reduces_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = train.adam_init(params)
        for _ in range(200):
            grads = {"w": 2.0 * params["w"]}
            params, state = train.adam_update(params, grads, state, lr=0.1)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.1

    def test_bias_correction_first_step(self):
        """First Adam step must be ~lr * sign(grad), not lr*(1-b1)*g."""
        params = {"w": jnp.asarray([0.0])}
        state = train.adam_init(params)
        params, _ = train.adam_update(params, {"w": jnp.asarray([1.0])}, state, lr=0.1)
        assert float(params["w"][0]) == pytest.approx(-0.1, rel=1e-3)


class TestTrainMlp:
    @pytest.fixture(scope="class")
    def dataset(self):
        return train.mlp_dataset()

    def test_float_training_learns(self, dataset):
        (xtr, ytr), (xte, yte) = dataset
        p, hist = train.train(
            model.mlp_forward, model.init_mlp(0), xtr, ytr, xte, yte,
            mode="float", steps=80, log_every=40,
        )
        assert hist["test_acc"][-1] > 0.9
        assert hist["loss"][-1] < hist["loss"][0]

    def test_qat_training_learns(self, dataset):
        (xtr, ytr), (xte, yte) = dataset
        p, hist = train.train(
            model.mlp_forward, model.init_mlp(0), xtr, ytr, xte, yte,
            mode="qat", bits=4, steps=60, log_every=30,
        )
        assert hist["test_acc"][-1] > 0.5, hist

    def test_et_regularizer_grows_thresholds(self, dataset):
        (xtr, ytr), (xte, yte) = dataset
        p0 = model.init_mlp(0)
        t0 = float(np.mean(np.abs(np.asarray(p0["bwht"]["t"]))))
        p, _ = train.train(
            model.mlp_forward, p0, xtr, ytr, xte, yte,
            mode="float", lam=0.05, t_max=1.0, steps=80, log_every=80,
        )
        t1 = float(np.mean(np.abs(np.asarray(p["bwht"]["t"]))))
        assert t1 > t0, f"Wald regularizer should grow |T|: {t0} -> {t1}"

    def test_evaluate_consistency(self, dataset):
        (xtr, ytr), (xte, yte) = dataset
        p = model.init_mlp(0)
        acc = train.evaluate(model.mlp_forward, p, xte, yte, mode="float")
        assert 0.0 <= acc <= 1.0


class TestExportWeights:
    def test_json_roundtrip(self, tmp_path):
        import json

        p = model.init_mlp(0)
        path = str(tmp_path / "w.json")
        train.export_weights(p, path)
        with open(path) as f:
            flat = json.load(f)
        assert flat["fc1.w"]["shape"] == [64, 64]
        assert len(flat["fc1.w"]["data"]) == 64 * 64
        np.testing.assert_allclose(
            np.asarray(flat["bwht.t"]["data"]),
            np.asarray(p["bwht"]["t"]),
            rtol=1e-6,
        )

"""Loss functions and the Eq. 8 early-termination regularizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses


class TestCrossEntropy:
    def test_uniform_logits(self):
        logits = jnp.zeros((4, 10))
        labels = jnp.asarray([0, 3, 5, 9])
        assert float(losses.cross_entropy(logits, labels)) == pytest.approx(
            np.log(10.0), rel=1e-5
        )

    def test_confident_correct_is_small(self):
        logits = jnp.asarray([[10.0, 0.0, 0.0]])
        labels = jnp.asarray([0])
        assert float(losses.cross_entropy(logits, labels)) < 1e-3

    def test_matches_manual(self):
        rng = np.random.RandomState(0)
        logits = jnp.asarray(rng.randn(6, 5).astype(np.float32))
        labels = jnp.asarray(rng.randint(0, 5, 6))
        p = np.exp(np.asarray(logits))
        p /= p.sum(-1, keepdims=True)
        manual = -np.mean(np.log(p[np.arange(6), np.asarray(labels)]))
        assert float(losses.cross_entropy(logits, labels)) == pytest.approx(
            manual, rel=1e-5
        )


class TestAccuracy:
    def test_perfect(self):
        logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
        assert float(losses.accuracy(logits, jnp.asarray([0, 1]))) == 1.0

    def test_half(self):
        logits = jnp.asarray([[1.0, 0.0], [1.0, 0.0]])
        assert float(losses.accuracy(logits, jnp.asarray([0, 1]))) == 0.5


class TestWaldRegularizer:
    def test_gradient_pushes_t_toward_tmax(self):
        """The combined loss term must *increase* |T| (Fig. 9a behaviour)."""
        t = jnp.asarray([0.2, -0.4, 0.7])

        def reg_term(t_):
            # as used in et_regularized_loss: loss -= lam * wald_nll
            return -losses.wald_neg_log_likelihood(t_, t_max=1.0)

        g = jax.grad(reg_term)(t)
        # d(loss)/dT must have opposite sign to T => -g/ sign ... gradient
        # descent step t <- t - lr*g should move |t| up.
        t2 = t - 0.01 * g
        assert (np.abs(np.asarray(t2)) > np.abs(np.asarray(t))).all()

    def test_minimum_at_g_equals_1(self):
        """Over (0,1], the term is minimized (most negative) at |T|=T_max."""
        vals = [
            -float(losses.wald_neg_log_likelihood(jnp.asarray([g])))
            for g in (0.1, 0.5, 0.99)
        ]
        assert vals[0] > vals[1] > vals[2]

    def test_eps_clip_keeps_finite(self):
        v = losses.wald_neg_log_likelihood(jnp.asarray([0.0, 1e-9]))
        assert np.isfinite(float(v))


class TestEtRegularizedLoss:
    def _setup(self):
        rng = np.random.RandomState(1)
        logits = jnp.asarray(rng.randn(8, 10).astype(np.float32))
        labels = jnp.asarray(rng.randint(0, 10, 8))
        ts = [jnp.asarray([0.1, 0.5]), jnp.asarray([-0.3])]
        return logits, labels, ts

    def test_lam_zero_is_plain_ce(self):
        logits, labels, ts = self._setup()
        assert float(
            losses.et_regularized_loss(logits, labels, ts, lam=0.0)
        ) == pytest.approx(float(losses.cross_entropy(logits, labels)))

    def test_larger_t_lowers_loss(self):
        logits, labels, _ = self._setup()
        small = losses.et_regularized_loss(
            logits, labels, [jnp.asarray([0.1])], lam=0.1
        )
        large = losses.et_regularized_loss(
            logits, labels, [jnp.asarray([0.9])], lam=0.1
        )
        assert float(large) < float(small)

    def test_gradient_through_thresholds(self):
        logits, labels, _ = self._setup()

        def f(t):
            return losses.et_regularized_loss(logits, labels, [t], lam=0.05)

        g = jax.grad(f)(jnp.asarray([0.3, -0.6]))
        assert np.isfinite(np.asarray(g)).all()
        # descent moves both toward +/-1
        t2 = np.asarray(jnp.asarray([0.3, -0.6]) - 0.1 * g)
        assert abs(t2[0]) > 0.3 and abs(t2[1]) > 0.6

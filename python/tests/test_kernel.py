"""Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes/seeds; every kernel must match ref.py to float32
tolerance (the quantized kernel must match bit-for-bit: identical sign
decisions, exact integer recombination).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import walsh
from compile.kernels import bitplane, bwht, ref, soft_threshold


def randn(shape, seed=0, scale=1.0):
    return jnp.asarray(
        (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)
    )


class TestWhtPallas:
    @pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 128])
    def test_matches_ref(self, n):
        x = randn((12, n), seed=n)
        np.testing.assert_allclose(
            bwht.wht_pallas(x), ref.wht_ref(x), rtol=1e-5, atol=1e-5
        )

    def test_batch_not_multiple_of_tile(self):
        x = randn((7, 16), seed=1)
        np.testing.assert_allclose(
            bwht.wht_pallas(x, batch_tile=4), ref.wht_ref(x), rtol=1e-5, atol=1e-5
        )

    def test_linearity(self):
        x, y = randn((5, 32), 2), randn((5, 32), 3)
        got = bwht.wht_pallas(x + 2.0 * y)
        want = bwht.wht_pallas(x) + 2.0 * bwht.wht_pallas(y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_involution_up_to_n(self):
        """W(W(x)) == n * x for the sequency-ordered transform."""
        x = randn((3, 16), 4)
        twice = bwht.wht_pallas(bwht.wht_pallas(x))
        np.testing.assert_allclose(twice, 16.0 * x, rtol=1e-4, atol=1e-4)

    @given(
        b=st.integers(1, 40),
        k=st.integers(2, 6),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_shapes(self, b, k, seed):
        x = randn((b, 1 << k), seed)
        np.testing.assert_allclose(
            bwht.wht_pallas(x), ref.wht_ref(x), rtol=1e-4, atol=1e-4
        )


class TestBwhtPallas:
    @pytest.mark.parametrize("dim", [20, 48, 160])
    def test_matches_ref(self, dim):
        padded = walsh.bwht_padded_dim(dim)
        x = randn((9, padded), dim)
        np.testing.assert_allclose(
            bwht.bwht_pallas(x), ref.bwht_ref(x), rtol=1e-5, atol=1e-5
        )

    def test_block_independence(self):
        """Zeroing one block's input zeroes only that block's output."""
        padded = walsh.bwht_padded_dim(20)  # [16, 4]
        x = randn((4, padded), 5)
        x0 = x.at[:, 16:].set(0.0)
        y = bwht.bwht_pallas(x0)
        assert np.allclose(y[:, 16:], 0.0)
        np.testing.assert_allclose(
            y[:, :16], bwht.bwht_pallas(x)[:, :16], rtol=1e-5
        )


class TestSoftThresholdPallas:
    @pytest.mark.parametrize("n", [8, 64, 100])
    def test_matches_ref(self, n):
        x = randn((17, n), n, scale=2.0)
        t = jnp.abs(randn((n,), n + 1, scale=0.5))
        np.testing.assert_allclose(
            soft_threshold.soft_threshold_pallas(x, t),
            ref.soft_threshold_ref(x, t),
            rtol=1e-5,
            atol=1e-6,
        )

    def test_dead_zone(self):
        x = jnp.asarray([[-0.5, -0.1, 0.0, 0.1, 0.5]], dtype=jnp.float32)
        t = jnp.full((5,), 0.2, jnp.float32)
        y = soft_threshold.soft_threshold_pallas(x, t)
        np.testing.assert_allclose(
            y, [[-0.3, 0.0, 0.0, 0.0, 0.3]], rtol=1e-6, atol=1e-7
        )

    def test_negative_t_treated_as_abs(self):
        x = randn((3, 8), 9)
        tpos = jnp.full((8,), 0.3, jnp.float32)
        np.testing.assert_allclose(
            soft_threshold.soft_threshold_pallas(x, -tpos),
            soft_threshold.soft_threshold_pallas(x, tpos),
        )

    @given(b=st.integers(1, 30), n=st.integers(2, 80), seed=st.integers(0, 99))
    @settings(max_examples=25, deadline=None)
    def test_hypothesis(self, b, n, seed):
        x = randn((b, n), seed, scale=3.0)
        t = jnp.abs(randn((n,), seed + 1))
        np.testing.assert_allclose(
            soft_threshold.soft_threshold_pallas(x, t),
            ref.soft_threshold_ref(x, t),
            rtol=1e-5,
            atol=1e-6,
        )


class TestBwhtLayerPallas:
    @pytest.mark.parametrize("dim", [16, 20, 96])
    def test_matches_ref(self, dim):
        padded = walsh.bwht_padded_dim(dim)
        x = randn((8, padded), dim)
        t = jnp.abs(randn((padded,), dim + 1, scale=0.3))
        np.testing.assert_allclose(
            bwht.bwht_layer_pallas(x, t),
            ref.bwht_layer_ref(x, t),
            rtol=1e-4,
            atol=1e-5,
        )

    def test_zero_threshold_is_identity(self):
        """T=0: transform then inverse reproduces the input exactly."""
        x = randn((4, 32), 11)
        t = jnp.zeros((32,), jnp.float32)
        np.testing.assert_allclose(
            bwht.bwht_layer_pallas(x, t), x, rtol=1e-4, atol=1e-5
        )

    def test_huge_threshold_kills_everything(self):
        x = randn((4, 32), 12)
        t = jnp.full((32,), 1e6, jnp.float32)
        np.testing.assert_allclose(
            bwht.bwht_layer_pallas(x, t), jnp.zeros_like(x), atol=1e-6
        )


class TestQuantBwhtPallas:
    @pytest.mark.parametrize("bits", [1, 2, 4, 8])
    def test_matches_ref_bitexact(self, bits):
        x = randn((16, 64), bits, scale=2.0)
        got = bitplane.quant_bwht_pallas(x, bits=bits)
        want = ref.quant_bwht_ref(x, bits=bits)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_output_values_are_quantized(self):
        """Outputs / scale must be integers in [-(2^B - 1), 2^B - 1]."""
        bits = 4
        x = randn((8, 16), 21)
        qmax = 2**bits - 1
        scale = float(jnp.max(jnp.abs(x))) / qmax
        y = np.asarray(bitplane.quant_bwht_pallas(x, bits=bits)) / scale
        np.testing.assert_allclose(y, np.round(y), atol=1e-3)
        assert np.abs(y).max() <= 2**bits - 1

    @given(
        b=st.integers(1, 20),
        k=st.integers(2, 6),
        bits=st.integers(1, 8),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=30, deadline=None)
    def test_hypothesis(self, b, k, bits, seed):
        x = randn((b, 1 << k), seed, scale=1.5)
        np.testing.assert_allclose(
            bitplane.quant_bwht_pallas(x, bits=bits),
            ref.quant_bwht_ref(x, bits=bits),
            rtol=1e-6,
            atol=1e-7,
        )

    def test_nonpow2_blocks(self):
        dim = walsh.bwht_padded_dim(20)
        x = randn((6, dim), 33)
        np.testing.assert_allclose(
            bitplane.quant_bwht_pallas(x, bits=6),
            ref.quant_bwht_ref(x, bits=6),
            rtol=1e-6,
            atol=1e-7,
        )

"""Walsh/Hadamard matrix and BWHT partition properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import walsh


class TestHadamard:
    def test_base_case(self):
        assert walsh.hadamard(0).tolist() == [[1]]

    def test_recursion(self):
        h1 = walsh.hadamard(1)
        assert h1.tolist() == [[1, 1], [1, -1]]
        h2 = walsh.hadamard(2)
        assert h2[:2, :2].tolist() == h1.tolist()
        assert h2[2:, 2:].tolist() == (-h1).tolist()

    @pytest.mark.parametrize("k", range(8))
    def test_orthogonality(self, k):
        h = walsh.hadamard(k).astype(np.int64)
        n = 1 << k
        assert (h @ h.T == n * np.eye(n, dtype=np.int64)).all()

    @pytest.mark.parametrize("k", range(8))
    def test_entries_pm1(self, k):
        assert set(np.unique(walsh.hadamard(k))) <= {-1, 1}

    def test_negative_k_raises(self):
        with pytest.raises(ValueError):
            walsh.hadamard(-1)


class TestWalsh:
    @pytest.mark.parametrize("k", range(1, 8))
    def test_sequency_order(self, k):
        w = walsh.walsh(k)
        seq = [walsh.sign_changes(r) for r in w]
        assert seq == list(range(1 << k)), "row i must have i sign changes"

    @pytest.mark.parametrize("k", range(7))
    def test_row_permutation_of_hadamard(self, k):
        h = {tuple(r) for r in walsh.hadamard(k)}
        w = {tuple(r) for r in walsh.walsh(k)}
        assert h == w

    @pytest.mark.parametrize("k", range(7))
    def test_orthogonality(self, k):
        w = walsh.walsh(k).astype(np.int64)
        n = 1 << k
        assert (w @ w.T == n * np.eye(n, dtype=np.int64)).all()

    def test_first_row_constant(self):
        assert (walsh.walsh(5)[0] == 1).all()

    def test_cached_immutable(self):
        w = walsh.walsh(3)
        with pytest.raises(ValueError):
            w[0, 0] = 5


class TestNextPow2:
    @pytest.mark.parametrize(
        "n,expect", [(1, 1), (2, 2), (3, 4), (5, 8), (16, 16), (17, 32), (1000, 1024)]
    )
    def test_values(self, n, expect):
        assert walsh.next_pow2(n) == expect

    def test_nonpositive_raises(self):
        with pytest.raises(ValueError):
            walsh.next_pow2(0)


class TestBwhtBlocks:
    def test_exact_pow2(self):
        assert walsh.bwht_blocks(64) == [64]
        assert walsh.bwht_blocks(128) == [128]

    def test_cap(self):
        assert walsh.bwht_blocks(256, max_block=128) == [128, 128]

    def test_mixed(self):
        assert walsh.bwht_blocks(20) == [16, 4]
        assert walsh.bwht_blocks(300) == [128, 128, 32, 8, 4]

    def test_small_remainder_pads(self):
        # 5 = 4 + 1; the 1-remainder becomes one padded MIN_BLOCK block.
        assert walsh.bwht_blocks(5) == [4, walsh.MIN_BLOCK]

    def test_invalid_max_block(self):
        with pytest.raises(ValueError):
            walsh.bwht_blocks(10, max_block=24)
        with pytest.raises(ValueError):
            walsh.bwht_blocks(10, max_block=2)

    @given(dim=st.integers(1, 4096), cap_k=st.integers(2, 10))
    @settings(max_examples=200, deadline=None)
    def test_properties(self, dim, cap_k):
        cap = 1 << cap_k
        blocks = walsh.bwht_blocks(dim, cap)
        # every block a power of two within [MIN_BLOCK, cap]
        for b in blocks:
            assert b & (b - 1) == 0
            assert walsh.MIN_BLOCK <= b <= cap
        total = sum(blocks)
        # covers dim, pads strictly less than MIN_BLOCK
        assert dim <= total < dim + walsh.MIN_BLOCK
        # non-increasing (greedy largest-first)
        assert blocks == sorted(blocks, reverse=True)


class TestBwhtMatrix:
    def test_block_diagonal(self):
        m = walsh.bwht_matrix(20)
        assert m.shape == (20, 20)
        assert (m[:16, 16:] == 0).all() and (m[16:, :16] == 0).all()
        assert (m[:16, :16] == walsh.walsh(4)).all()
        assert (m[16:, 16:] == walsh.walsh(2)).all()

    @pytest.mark.parametrize("dim", [4, 7, 16, 20, 100, 300])
    def test_blockwise_orthogonality(self, dim):
        m = walsh.bwht_matrix(dim).astype(np.int64)
        gram = m @ m.T
        # Gram matrix is diagonal with block sizes on the diagonal.
        assert (gram == np.diag(np.diag(gram))).all()
        blocks = walsh.bwht_blocks(dim)
        expect = np.concatenate([np.full(b, b) for b in blocks])
        assert (np.diag(gram) == expect).all()

    def test_padded_dim_consistency(self):
        for dim in [1, 3, 5, 20, 64, 129, 300]:
            assert walsh.bwht_padded_dim(dim) == walsh.bwht_matrix(dim).shape[0]

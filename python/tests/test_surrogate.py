"""Surrogate gradient machinery (Eqs. 6-7, Fig. 7) and the STE wrapper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import surrogate
from compile.kernels import ref


class TestSignApprox:
    def test_converges_to_sign(self):
        # Stay off the discontinuity at 0 (float32 linspace lands a ~1e-8
        # residue there where sign() and tanh() legitimately disagree).
        x = jnp.asarray(np.r_[-2:-0.01:50j, 0.01:2:50j].astype(np.float32))
        approx = surrogate.sign_approx(x, tau=500.0)
        np.testing.assert_allclose(
            np.asarray(approx), np.sign(np.asarray(x)), atol=1e-2
        )

    def test_monotone_in_tau(self):
        """Higher tau sharpens: |tanh(tau x)| grows with tau off zero."""
        x = jnp.asarray([0.1, -0.3])
        a1 = jnp.abs(surrogate.sign_approx(x, 2.0))
        a2 = jnp.abs(surrogate.sign_approx(x, 8.0))
        assert (np.asarray(a2) >= np.asarray(a1)).all()

    def test_grad_peak_at_zero(self):
        g0 = surrogate.sign_approx_grad(jnp.asarray(0.0), 4.0)
        g1 = surrogate.sign_approx_grad(jnp.asarray(1.0), 4.0)
        assert float(g0) == pytest.approx(4.0)
        assert float(g0) > float(g1)

    def test_grad_matches_autodiff(self):
        f = lambda x: surrogate.sign_approx(x, 3.0)
        x = jnp.asarray(0.37)
        auto = jax.grad(f)(x)
        manual = surrogate.sign_approx_grad(x, 3.0)
        np.testing.assert_allclose(float(auto), float(manual), rtol=1e-6)


class TestBitApprox:
    def test_high_tau_matches_true_bit(self):
        """Eq. 7 at high tau reproduces the magnitude-bit staircase.

        Eq. 4's b is 1-indexed from the LSB (weight 2^(b-1)); Eq. 7's sin
        argument 2pi*2^(bmax-b)*x/xmax with xmax=2^bmax has period 2^b in
        x, i.e. plane p = b-1 of floor(x).  Sample at integer+0.5 so we sit
        mid-staircase, away from the sigmoid's 0.5-crossings.
        """
        bmax = 4
        xmax = float(2**bmax)
        ns = np.arange(0, 16)
        xs = jnp.asarray((ns + 0.5).astype(np.float32))
        for b in range(1, bmax + 1):
            approx = surrogate.bit_approx(xs, b, bmax, xmax, tau=200.0)
            true_bit = (ns >> (b - 1)) & 1
            agree = np.mean((np.asarray(approx) > 0.5) == (true_bit == 1))
            assert agree == 1.0, f"bit {b}: agreement {agree}"

    def test_output_in_unit_interval(self):
        xs = jnp.linspace(0.0, 8.0, 64)
        y = surrogate.bit_approx(xs, 2, 4, 8.0, tau=5.0)
        assert float(jnp.min(y)) >= 0.0 and float(jnp.max(y)) <= 1.0

    def test_differentiable(self):
        g = jax.grad(lambda x: surrogate.bit_approx(x, 2, 4, 8.0, 5.0))(
            jnp.asarray(3.3)
        )
        assert np.isfinite(float(g))


class TestTauSchedule:
    def test_endpoints(self):
        assert surrogate.tau_schedule(0, 100, 1.0, 32.0) == pytest.approx(1.0)
        assert surrogate.tau_schedule(99, 100, 1.0, 32.0) == pytest.approx(32.0)

    def test_monotone(self):
        vals = [surrogate.tau_schedule(s, 50) for s in range(50)]
        assert vals == sorted(vals)

    def test_degenerate_total(self):
        assert surrogate.tau_schedule(0, 1, 1.0, 8.0) == 8.0


class TestQuantBwhtSte:
    def test_forward_is_exact_hardware_math(self):
        x = jnp.asarray(np.random.RandomState(0).randn(8, 32).astype(np.float32))
        got = surrogate.quant_bwht_ste(x, 8, 128, 8.0)
        want = ref.quant_bwht_ref(x, 8, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_gradient_finite_and_nonzero(self):
        x = jnp.asarray(np.random.RandomState(1).randn(4, 16).astype(np.float32))

        def loss(x_):
            return jnp.sum(surrogate.quant_bwht_ste(x_, 4, 128, 8.0) ** 2)

        g = jax.grad(loss)(x)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.max(jnp.abs(g))) > 0.0

    def test_gradient_descends(self):
        """A few surrogate-gradient steps must reduce a simple loss."""
        rng = np.random.RandomState(2)
        x = jnp.asarray(rng.randn(8, 16).astype(np.float32))
        target = jnp.asarray(rng.randn(8, 16).astype(np.float32)) * 2.0

        def loss(x_):
            y = surrogate.quant_bwht_ste(x_, 8, 128, 16.0)
            return jnp.mean((y - target) ** 2)

        l0 = float(loss(x))
        g = jax.grad(loss)
        for _ in range(30):
            x = x - 0.05 * g(x)
        l1 = float(loss(x))
        assert l1 < l0, f"surrogate descent failed: {l0} -> {l1}"

    @given(bits=st.integers(1, 8), seed=st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_forward_hypothesis(self, bits, seed):
        x = jnp.asarray(
            np.random.RandomState(seed).randn(4, 16).astype(np.float32)
        )
        np.testing.assert_allclose(
            np.asarray(surrogate.quant_bwht_ste(x, bits, 128, 8.0)),
            np.asarray(ref.quant_bwht_ref(x, bits, 128)),
            rtol=1e-6,
        )


class TestQuantBwhtSoft:
    def test_converges_to_hard_at_high_tau(self):
        x = jnp.asarray(np.random.RandomState(3).randn(8, 32).astype(np.float32))
        soft = surrogate.quant_bwht_soft(x, 8, 128, tau=5000.0)
        hard = ref.quant_bwht_ref(x, 8, 128)
        # Off exact-zero PSUMs, tanh(5000*psum/n) ~ sign.
        close = np.mean(
            np.abs(np.asarray(soft) - np.asarray(hard))
            < 0.05 * float(jnp.max(jnp.abs(hard)))
        )
        assert close > 0.9

    def test_smooth_everywhere(self):
        x = jnp.asarray(np.random.RandomState(4).randn(2, 8).astype(np.float32))
        g = jax.grad(
            lambda x_: jnp.sum(surrogate.quant_bwht_soft(x_, 4, 128, 3.0))
        )(x)
        assert np.isfinite(np.asarray(g)).all()

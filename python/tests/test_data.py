"""Synthetic dataset generators: determinism, shapes, learnability signal."""

import numpy as np

from compile import data


class TestImageDataset:
    def test_shapes_and_dtypes(self):
        x, y = data.make_image_dataset(n=64, h=16, w=16, c=3)
        assert x.shape == (64, 16, 16, 3) and x.dtype == np.float32
        assert y.shape == (64,) and y.dtype == np.int32

    def test_deterministic(self):
        x1, y1 = data.make_image_dataset(n=32, seed=5)
        x2, y2 = data.make_image_dataset(n=32, seed=5)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_seed_changes_data(self):
        x1, _ = data.make_image_dataset(n=32, seed=1)
        x2, _ = data.make_image_dataset(n=32, seed=2)
        assert not np.allclose(x1, x2)

    def test_all_classes_present(self):
        _, y = data.make_image_dataset(n=512)
        assert set(np.unique(y)) == set(range(10))

    def test_class_signal_exists(self):
        """Same-class images must correlate more than cross-class ones."""
        x, y = data.make_image_dataset(n=256, noise=0.2)
        flat = x.reshape(len(x), -1)
        flat = flat - flat.mean(0)
        c0 = flat[y == 0][:10]
        c1 = flat[y == 1][:10]
        intra = np.mean([np.corrcoef(a, b)[0, 1] for a in c0[:5] for b in c0[5:]])
        inter = np.mean([np.corrcoef(a, b)[0, 1] for a in c0[:5] for b in c1[:5]])
        assert intra > inter


class TestVectorDataset:
    def test_shapes(self):
        x, y = data.make_vector_dataset(n=128, dim=64)
        assert x.shape == (128, 64) and y.shape == (128,)

    def test_deterministic(self):
        a = data.make_vector_dataset(n=64, seed=9)
        b = data.make_vector_dataset(n=64, seed=9)
        np.testing.assert_array_equal(a[0], b[0])

    def test_linearly_separable_enough(self):
        """Nearest-prototype classification must beat chance by far."""
        x, y = data.make_vector_dataset(n=1000, noise=0.6, seed=1)
        protos = np.stack([x[y == c].mean(0) for c in range(10)])
        pred = np.argmax(x @ protos.T, axis=1)
        assert (pred == y).mean() > 0.6


class TestSplit:
    def test_sizes_and_disjoint(self):
        x, y = data.make_vector_dataset(n=100)
        (xtr, ytr), (xte, yte) = data.train_test_split(x, y, test_frac=0.2)
        assert len(xtr) == 80 and len(xte) == 20
        # disjoint row multisets (vectors are continuous: collision ~ 0)
        tr_set = {tuple(np.round(r, 5)) for r in xtr[:, :4]}
        te_set = {tuple(np.round(r, 5)) for r in xte[:, :4]}
        assert not (tr_set & te_set)

    def test_deterministic(self):
        x, y = data.make_vector_dataset(n=50)
        s1 = data.train_test_split(x, y, seed=3)
        s2 = data.train_test_split(x, y, seed=3)
        np.testing.assert_array_equal(s1[0][0], s2[0][0])


class TestExport:
    def test_npy_roundtrip(self, tmp_path):
        x, y = data.make_vector_dataset(n=16)
        prefix = str(tmp_path / "ds")
        data.export_npy(prefix, x, y)
        np.testing.assert_array_equal(np.load(prefix + "_x.npy"), x)
        np.testing.assert_array_equal(np.load(prefix + "_y.npy"), y)

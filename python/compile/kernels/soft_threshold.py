"""Pallas kernel for the soft-threshold activation S_T (Eq. 3).

S_T(x) = sign(x) * (|x| - T)_+ — the paper's replacement for ReLU in the
frequency domain: it keeps high-magnitude *negative* coefficients, which
carry as much spectral energy as positive ones, and its dead zone
|x| <= T is exactly what the predictive early-termination scheduler
exploits (any output whose PSUM bounds stay inside [-T, T] is known-zero).

Pure VPU elementwise work; grid tiles the batch so arbitrarily large
activations stream through a fixed VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _soft_threshold_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = jnp.abs(t_ref[...])
    o_ref[...] = jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@functools.partial(jax.jit, static_argnames=("tile",))
def soft_threshold_pallas(
    x: jnp.ndarray, t: jnp.ndarray, tile: int = DEFAULT_TILE
) -> jnp.ndarray:
    """S_T over a (batch, channels) array; t is per-channel (channels,)."""
    b, n = x.shape
    assert t.shape == (n,), f"t must be per-channel ({n},), got {t.shape}"
    tb = min(tile, b)
    return pl.pallas_call(
        _soft_threshold_kernel,
        grid=(pl.cdiv(b, tb),),
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, t)

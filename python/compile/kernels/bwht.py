"""Pallas kernels for the (blockwise) Walsh-Hadamard transform.

Hardware adaptation (DESIGN.md §2): the paper's analog crossbar hardwires a
+/-1 Walsh block per 16x16 tile and computes the transform as a single
charge-domain matvec.  On TPU the equivalent mapping is a dense matmul on
the MXU with the Walsh block resident in VMEM — for block sizes <= 1024 the
dense systolic form beats the O(N log N) butterfly because every butterfly
stage would round-trip through VPU adds while the MXU does the whole block
in one pass.  BlockSpec keeps one (batch-tile, block) pair in VMEM per grid
step, which is the software analog of stitching a BWHT block onto one
crossbar tile.

All kernels run with interpret=True: CPU PJRT cannot execute Mosaic
custom-calls, and correctness (vs. ref.py) is the build-time signal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from compile import walsh as walsh_mod

# Batch tile: multiple of 8 to stay MXU/VPU-shaped on real hardware.
DEFAULT_BATCH_TILE = 64


def _wht_kernel(x_ref, w_ref, o_ref):
    """One grid step: (tile_b, n) @ (n, n)^T with the block in VMEM."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("batch_tile",))
def wht_pallas(
    x: jnp.ndarray, batch_tile: int = DEFAULT_BATCH_TILE
) -> jnp.ndarray:
    """Sequency-ordered WHT along the last axis of a 2-D (batch, n) array.

    n must be a power of two.  Grid is over batch tiles only; the whole
    Walsh block rides along each step (it is parameter-free and tiny:
    a 128x128 f32 block is 64 KiB — comfortably VMEM-resident next to the
    batch tile).
    """
    b, n = x.shape
    k = int(np.log2(n))
    assert 1 << k == n, f"dim {n} not a power of two"
    w = jnp.asarray(walsh_mod.walsh(k), dtype=x.dtype)
    tile = min(batch_tile, b)
    grid = (pl.cdiv(b, tile),)
    return pl.pallas_call(
        _wht_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, w)


def bwht_pallas(
    x: jnp.ndarray,
    max_block: int = 128,
    batch_tile: int = DEFAULT_BATCH_TILE,
) -> jnp.ndarray:
    """Blockwise WHT: one wht_pallas call per BWHT block (pre-padded input).

    Each block is an independent crossbar tile in hardware; here each is an
    independent pallas_call, which XLA schedules back-to-back over disjoint
    slices (no inter-block data dependence).
    """
    dim = x.shape[-1]
    blocks = walsh_mod.bwht_blocks(dim, max_block)
    assert sum(blocks) == dim, (
        f"input must be padded to {sum(blocks)} (got {dim})"
    )
    outs = []
    off = 0
    for blk in blocks:
        outs.append(wht_pallas(x[:, off : off + blk], batch_tile))
        off += blk
    return jnp.concatenate(outs, axis=-1)


def _bwht_layer_kernel(x_ref, w_ref, t_ref, o_ref):
    """Fused BWHT -> soft-threshold -> inverse BWHT for one block.

    Uses the orthonormal Walsh form (W/sqrt(n) is its own inverse), so the
    round trip is x @ Wn^T -> S_T -> @ Wn^T.  Fusing keeps the intermediate
    frequency-domain tile in VMEM — the analog of the paper never
    materializing the transform outside the crossbar.
    """
    n = w_ref.shape[0]
    inv_sqrt_n = 1.0 / jnp.sqrt(jnp.float32(n))
    wn = w_ref[...].astype(jnp.float32) * inv_sqrt_n
    y = jnp.dot(x_ref[...], wn.T, preferred_element_type=jnp.float32)
    t = jnp.abs(t_ref[...])
    y = jnp.sign(y) * jnp.maximum(jnp.abs(y) - t, 0.0)
    o_ref[...] = jnp.dot(y, wn.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("batch_tile",))
def _wht_layer_block_pallas(
    x: jnp.ndarray, t: jnp.ndarray, batch_tile: int = DEFAULT_BATCH_TILE
) -> jnp.ndarray:
    b, n = x.shape
    k = int(np.log2(n))
    assert 1 << k == n
    w = jnp.asarray(walsh_mod.walsh(k), dtype=jnp.float32)
    tile = min(batch_tile, b)
    return pl.pallas_call(
        _bwht_layer_kernel,
        grid=(pl.cdiv(b, tile),),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, w, t)


def bwht_layer_pallas(
    x: jnp.ndarray,
    t: jnp.ndarray,
    max_block: int = 128,
    batch_tile: int = DEFAULT_BATCH_TILE,
) -> jnp.ndarray:
    """Fused blockwise transform->threshold->inverse layer (Fig. 2 flow)."""
    dim = x.shape[-1]
    blocks = walsh_mod.bwht_blocks(dim, max_block)
    assert sum(blocks) == dim
    outs = []
    off = 0
    for blk in blocks:
        outs.append(
            _wht_layer_block_pallas(
                x[:, off : off + blk], t[off : off + blk], batch_tile
            )
        )
        off += blk
    return jnp.concatenate(outs, axis=-1)

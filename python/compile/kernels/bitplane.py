"""Pallas kernel for the ADC/DAC-free quantized transform (Eq. 4).

This is the exact arithmetic the analog crossbar performs (Fig. 6):

  1. the input vector is quantized to sign-magnitude bitplanes (DAC-free
     input streaming: one bitplane per 2-clock crossbar operation),
  2. each bitplane's +/-1 entries multiply the hardwired +/-1 Walsh block —
     in hardware a conditional discharge of local nodes O/OB,
  3. the row-wise charge average is collapsed to ONE bit by the comparator
     (sign()) — this is what makes the design ADC-free,
  4. per-bitplane output bits are recombined with binary weights 2^(b-1).

On TPU the B bitplanes become B dense +/-1 matmuls on the MXU over the same
VMEM-resident Walsh block (unrolled loop — B is a small static constant, and
each iteration is an independent MXU pass so the unroll pipelines cleanly).
Early termination is deliberately NOT in this kernel: it is data-dependent
control flow that would stall the MXU; the paper likewise implements it in
digital peripherals (Fig. 10), which for us is the rust L3 scheduler.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from compile import walsh as walsh_mod

DEFAULT_BATCH_TILE = 64


def _quant_bwht_kernel(q_ref, w_ref, o_ref, *, bits: int):
    """One grid step of Eq. (4) on a (tile_b, n) tile of quantized inputs.

    q_ref holds signed integers (float-carried).  The bitplane loop is
    unrolled: plane b extracts sign(q) * bit_b(|q|) in the VPU, the +/-1
    matvec runs on the MXU, the comparator is jnp.sign.
    """
    q = q_ref[...]
    w_t = w_ref[...].T.astype(jnp.float32)
    sign = jnp.sign(q)
    mag = jnp.abs(q).astype(jnp.int32)
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for b in range(bits):
        plane = sign * ((mag >> b) & 1).astype(jnp.float32)
        psum = jnp.dot(plane, w_t, preferred_element_type=jnp.float32)
        acc = acc + jnp.sign(psum) * jnp.float32(2.0**b)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bits", "batch_tile"))
def quant_wht_pallas(
    q: jnp.ndarray, bits: int = 8, batch_tile: int = DEFAULT_BATCH_TILE
) -> jnp.ndarray:
    """Eq. (4) over one power-of-two Walsh block.

    q: (batch, n) integer-valued (already quantized; scale handled by the
    caller so the kernel matches the hardware bit-for-bit).  Returns the
    integer-valued recombined output (scale NOT applied).
    """
    b, n = q.shape
    k = int(np.log2(n))
    assert 1 << k == n, f"dim {n} not a power of two"
    w = jnp.asarray(walsh_mod.walsh(k), dtype=jnp.float32)
    tile = min(batch_tile, b)
    kernel = functools.partial(_quant_bwht_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(b, tile),),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(q, w)


def quant_bwht_pallas(
    x: jnp.ndarray,
    bits: int = 8,
    max_block: int = 128,
    batch_tile: int = DEFAULT_BATCH_TILE,
) -> jnp.ndarray:
    """Full Eq. (4) pipeline: quantize -> blockwise kernel -> rescale.

    Matches ref.quant_bwht_ref exactly (same quantizer, same sign(0)=0
    comparator convention).
    """
    dim = x.shape[-1]
    blocks = walsh_mod.bwht_blocks(dim, max_block)
    assert sum(blocks) == dim, f"input must be padded to {sum(blocks)}"
    qmax = float(2**bits - 1)  # sign-magnitude: `bits` magnitude planes
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    outs = []
    off = 0
    for blk in blocks:
        outs.append(quant_wht_pallas(q[:, off : off + blk], bits, batch_tile))
        off += blk
    return jnp.concatenate(outs, axis=-1) * scale

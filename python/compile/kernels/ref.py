"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suites compare kernels
against, and the "exact digital" baseline the rust side cross-checks via
the AOT artifacts.  No pallas, no tricks — straightforward jnp.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from compile import walsh as walsh_mod


def wht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Exact sequency-ordered WHT along the last axis (power-of-two dim)."""
    n = x.shape[-1]
    k = int(np.log2(n))
    assert 1 << k == n, f"dim {n} not a power of two"
    w = jnp.asarray(walsh_mod.walsh(k), dtype=x.dtype)
    return x @ w.T


def bwht_ref(x: jnp.ndarray, max_block: int = 128) -> jnp.ndarray:
    """Blockwise WHT along the last (channel) axis; input pre-padded."""
    dim = x.shape[-1]
    m = jnp.asarray(walsh_mod.bwht_matrix(dim, max_block), dtype=x.dtype)
    assert m.shape[0] == dim, (
        f"input must be padded to {m.shape[0]} (got {dim}); "
        "use walsh.bwht_padded_dim"
    )
    return x @ m.T


def soft_threshold_ref(x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """S_T(x) = sign(x) * max(|x| - T, 0)  (Eq. 3). t broadcasts over x."""
    t = jnp.abs(t)
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def quantize_ref(x: jnp.ndarray, bits: int):
    """Symmetric sign-magnitude quantization to ``bits`` magnitude bitplanes.

    The hardware streams the sign on CL/CLB and ``bits`` magnitude
    bitplanes (Fig. 6), so the integer range is +/-(2^bits - 1).  Returns
    (q, scale): q as float-held ints, scale such that x ~= q * scale.
    bits=1 is the extreme DAC-free case: q in {-1, 0, +1}.
    """
    qmax = float(2**bits - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q, scale


def bitplanes_ref(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Sign-magnitude bitplane decomposition (Fig. 6 input streaming).

    q: integer-valued array (float dtype ok).  Returns planes of shape
    (bits, *q.shape) with values in {-1, 0, +1}: plane b holds
    sign(q) * bit_b(|q|), b=0 is the LSB.  This mirrors the hardware's
    CL/CLB encoding: magnitude bit gated onto the positive or negative
    column line by the sign.
    """
    sign = jnp.sign(q)
    mag = jnp.abs(q).astype(jnp.int32)
    planes = [
        (sign * ((mag >> b) & 1).astype(q.dtype)) for b in range(bits)
    ]
    return jnp.stack(planes, axis=0)


def quant_bwht_ref(
    x: jnp.ndarray, bits: int, max_block: int = 128
) -> jnp.ndarray:
    """Eq. (4): the exact function the ADC-free crossbar computes.

    F0_i(x) = sum_b sign( sum_j I_jb * B_ij ) * 2^(b-1)

    Input is quantized to ``bits`` sign-magnitude bitplanes; each bitplane's
    +/-1 matvec against the BWHT matrix is collapsed to 1 bit by sign()
    (the row comparator), then recombined with binary weights.  Output is
    rescaled by the input quantization scale so it approximates bwht_ref.

    sign() here maps 0 -> 0 (an exactly balanced charge share trips neither
    way; the hardware comparator resolves randomly, training treats it as 0).
    """
    dim = x.shape[-1]
    m = jnp.asarray(
        walsh_mod.bwht_matrix(dim, max_block), dtype=x.dtype
    )
    q, scale = quantize_ref(x, bits)
    planes = bitplanes_ref(q, bits)  # (bits, ..., dim)
    psum = planes @ m.T  # (bits, ..., dim)
    obits = jnp.sign(psum)
    weights = (2.0 ** jnp.arange(bits, dtype=x.dtype)).reshape(
        (bits,) + (1,) * x.ndim
    )
    y = jnp.sum(obits * weights, axis=0)
    # Rescale: the comparator output is +/-1 per plane; the natural
    # magnitude is the input scale (training absorbs residual gain into T
    # and downstream normalization).
    return y * scale


def bwht_layer_ref(
    x: jnp.ndarray, t: jnp.ndarray, max_block: int = 128
) -> jnp.ndarray:
    """Full float BWHT layer: transform -> soft-threshold -> inverse.

    The WHT is (up to scale) its own inverse: W W^T = N I per block, so we
    use the orthonormal form (1/sqrt(N) each way) for numerical symmetry.
    """
    dim = x.shape[-1]
    blocks = walsh_mod.bwht_blocks(dim, max_block)
    m = jnp.asarray(walsh_mod.bwht_matrix(dim, max_block), dtype=x.dtype)
    norm = jnp.concatenate(
        [
            jnp.full((b,), 1.0 / np.sqrt(float(b)), dtype=x.dtype)
            for b in blocks
        ]
    )
    y = (x @ m.T) * norm
    y = soft_threshold_ref(y, t)
    return (y @ m.T) * norm

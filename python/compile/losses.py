"""Loss functions, including the early-termination regularizer (Eq. 8).

  L_mod = L_acc(T) - lambda * log( sqrt(1/g(T)^3) * exp(-g(T)/2) )
        = L_acc(T) + lambda * ( (3/2) log g(T) + g(T)/2 )      [up to const]

with g(T) = |T / T_max|.  The second term is (minus) the log-likelihood of
|T| under an inverted-Gaussian (Wald) shape on (0, 1]; minimizing it drives
g(T) toward 1, i.e. T toward +/-T_max, maximizing the soft-threshold dead
zone and therefore the early-termination opportunities (Fig. 9a).

NOTE the sign: the Wald log-density  -3/2 log g - g/2  is *maximized* at
g -> 1 over (0,1] boundary-constrained training (its unconstrained mode is
at g = 3 - sqrt(... ) < 1 for mu=1, lambda=1 parameterization; with the
paper's normalization g in (0,1] the gradient points toward larger |T|).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are integer class ids."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def wald_neg_log_likelihood(
    t: jnp.ndarray, t_max: float = 1.0, eps: float = 1e-4
) -> jnp.ndarray:
    """-log( sqrt(1/g^3) * exp(-g/2) ) summed over thresholds (Eq. 8 term).

    g = |t|/t_max clipped into (eps, 1] so the log stays finite; the
    gradient w.r.t. t pushes |t| toward t_max.
    """
    g = jnp.clip(jnp.abs(t) / t_max, eps, 1.0)
    return jnp.sum(1.5 * jnp.log(g) + 0.5 * g)


def et_regularized_loss(
    logits: jnp.ndarray,
    labels: jnp.ndarray,
    thresholds: list[jnp.ndarray] | tuple[jnp.ndarray, ...],
    lam: float = 0.0,
    t_max: float = 1.0,
) -> jnp.ndarray:
    """Eq. (8): accuracy loss + lambda * Wald regularizer over all T vectors.

    The regularizer is *subtracted* log-likelihood; because the Wald
    density as normalized by the paper increases toward g=1 on (0,1],
    the combined sign drives T toward +/-T_max.  lam=0 recovers plain
    cross-entropy (the "without early termination" training mode).
    """
    loss = cross_entropy(logits, labels)
    if lam > 0.0:
        reg = sum(wald_neg_log_likelihood(t, t_max) for t in thresholds)
        # Sign note: Eq. (8) as printed (L_acc - lam*log(sqrt(1/g^3)e^{-g/2}))
        # expands to L_acc + lam*(1.5 log g + g/2), whose minimizer drives
        # g -> 0 — the opposite of the paper's own text and Fig. 9a (T is
        # "driven towards -1 and 1").  We therefore use the sign that
        # realizes the reported behaviour: total = L_acc - lam*(1.5 log g
        # + g/2), strictly decreasing in g on (0, 1], pushing |T| -> T_max.
        # (Equivalently: the printed density's fraction is inverted.)
        # EXPERIMENTS.md records this as a paper erratum.
        loss = loss - lam * reg
    return loss

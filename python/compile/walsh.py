"""Walsh-Hadamard matrix construction and blockwise (BWHT) partitioning.

Implements the paper's Sec. II-A:
  * Sylvester Hadamard matrices H_k (Eq. 2),
  * Walsh (sequency-ordered) matrices W_k — rows of H_k reordered by the
    number of sign changes,
  * blockwise partitioning for input dims that are not powers of two
    (BWHT, Pan et al. [26]): split the transform into power-of-two blocks
    so only the last block is zero-padded.

Everything here is parameter-free and deterministic; these matrices are the
"weights" the analog crossbar hardwires as +1/-1 cells.
"""

from __future__ import annotations

import functools

import numpy as np


def hadamard(k: int) -> np.ndarray:
    """Sylvester Hadamard matrix H_k of size 2^k x 2^k (Eq. 2)."""
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    h = np.array([[1]], dtype=np.int8)
    for _ in range(k):
        h = np.block([[h, h], [h, -h]])
    return h


def sign_changes(row: np.ndarray) -> int:
    """Number of sign changes along a +/-1 row (the row's sequency)."""
    return int(np.sum(row[:-1] != row[1:]))


@functools.lru_cache(maxsize=32)
def _walsh_cached(k: int) -> np.ndarray:
    h = hadamard(k)
    order = np.argsort([sign_changes(r) for r in h], kind="stable")
    w = h[order]
    w.setflags(write=False)
    return w


def walsh(k: int) -> np.ndarray:
    """Walsh matrix W_k: rows of H_k in increasing sequency order.

    Row i has exactly i sign changes; rows are mutually orthogonal and
    W_k @ W_k.T == 2^k * I.
    """
    return _walsh_cached(k)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    return 1 << (n - 1).bit_length()


MIN_BLOCK = 4


def bwht_blocks(dim: int, max_block: int = 128) -> list[int]:
    """BWHT block sizes covering ``dim`` channels (Pan et al. [26]).

    Greedy largest-power-of-two-that-fits partition, capped at
    ``max_block`` (the crossbar tile-size budget) and floored at
    ``MIN_BLOCK`` (a 1- or 2-point WHT carries no frequency content).
    Only the final block may require zero-padding, and only when the
    remainder is smaller than MIN_BLOCK — this mitigates the worst-case
    excessive zero-padding of a single full-size transform (e.g. dim=20
    gives [16, 4] with no padding instead of one 32-block padding 12).
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    if max_block & (max_block - 1) or max_block < MIN_BLOCK:
        raise ValueError(
            f"max_block must be a power of two >= {MIN_BLOCK}, got {max_block}"
        )
    blocks: list[int] = []
    rem = dim
    while rem >= MIN_BLOCK:
        b = min(1 << (rem.bit_length() - 1), max_block)
        blocks.append(b)
        rem -= b
    if rem > 0:
        # Final sub-MIN_BLOCK remainder: one zero-padded MIN_BLOCK block.
        blocks.append(MIN_BLOCK)
    return blocks


def bwht_matrix(dim: int, max_block: int = 128) -> np.ndarray:
    """Dense block-diagonal BWHT matrix for ``dim`` channels.

    Output is padded_dim x padded_dim where padded_dim = sum(bwht_blocks).
    Callers zero-pad inputs to padded_dim.  Entries are +/-1 within blocks
    and 0 elsewhere; this is the exact matrix the crossbar tiles implement.
    """
    blocks = bwht_blocks(dim, max_block)
    padded = sum(blocks)
    m = np.zeros((padded, padded), dtype=np.int8)
    off = 0
    for b in blocks:
        k = int(np.log2(b))
        m[off : off + b, off : off + b] = walsh(k)
        off += b
    return m


def bwht_padded_dim(dim: int, max_block: int = 128) -> int:
    return sum(bwht_blocks(dim, max_block))

"""Surrogate gradients for the non-differentiable quantized transform.

The ADC-free forward path (Eq. 4) composes two discontinuous functions:
the comparator sign() and the bitplane quantizer I_b().  The paper trains
through them with the continuous approximations

  sign(x)  ~ tanh(tau * x)                                   (Eq. 6)
  I_b(x)   ~ sigmoid(-tau * sin(2*pi * 2^(bmax-b) * x/xmax)) (Eq. 7)

annealing tau upward over training so the surrogate sharpens toward the
true staircase without creating sharp local minima early on.

We expose both (a) the raw approximation functions (used to regenerate
Fig. 7 and by the "soft" forward mode), and (b) a straight-through
custom_vjp wrapper ``quant_bwht_ste`` whose forward is the *exact*
hardware arithmetic (bit-for-bit Eq. 4) and whose backward is the
tanh-surrogate derivative chained through the float transform — the
standard way Eq. (5b) is realized in an autodiff framework.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile import walsh as walsh_mod
from compile.kernels import ref


def sign_approx(x: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Eq. (6): tanh(tau*x) -> sign(x) as tau -> inf."""
    return jnp.tanh(x * tau)


def sign_approx_grad(x: jnp.ndarray, tau: float) -> jnp.ndarray:
    """d/dx tanh(tau*x) = tau * sech^2(tau*x)."""
    t = jnp.tanh(x * tau)
    return tau * (1.0 - t * t)


def bit_approx(
    x: jnp.ndarray, b: int, bmax: int, xmax: float, tau: float
) -> jnp.ndarray:
    """Eq. (7): smooth approximation to the b-th magnitude bit of x.

    b is 1-indexed from the MSB side as in the paper (b=1 is the MSB,
    b=bmax the LSB); the sin term's period doubles with significance so
    the logistic staircase matches the true bit pattern as tau -> inf.
    """
    arg = -tau * jnp.sin(2.0 * jnp.pi * (2.0 ** (bmax - b)) * x / xmax)
    # exp(arg)/(1+exp(arg)) as printed overflows for arg > ~88 in f32;
    # sigmoid(arg) is the same function, numerically stable.
    return jax.nn.sigmoid(arg)


def tau_schedule(
    step: int, total_steps: int, tau_min: float = 1.0, tau_max: float = 32.0
) -> float:
    """Geometric tau annealing: sharpen the surrogate as training proceeds."""
    if total_steps <= 1:
        return tau_max
    frac = min(max(step / (total_steps - 1), 0.0), 1.0)
    return float(tau_min * (tau_max / tau_min) ** frac)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def quant_bwht_ste(
    x: jnp.ndarray, bits: int, max_block: int, tau: float
) -> jnp.ndarray:
    """Exact Eq. (4) forward with a surrogate backward (Eq. 5b).

    Forward: bit-for-bit the crossbar arithmetic (ref.quant_bwht_ref).
    Backward: gradient of the tau-smoothed transform
      y_i ~ scale * sum_b tanh(tau_n * psum_ib) * 2^(b-1)
    where psum flows through the float +/-1 matmul, i.e. dL/dx gets
    sign'(psum) ~ tau*sech^2 chained with B_ij, and the bitplane
    decomposition is treated straight-through (dI_jb/dx_j ~ 2^-(b-1) share
    of the quantizer slope, which telescopes to 1/scale).
    """
    return ref.quant_bwht_ref(x, bits, max_block)


def _quant_bwht_fwd(x, bits, max_block, tau):
    return ref.quant_bwht_ref(x, bits, max_block), x


def _quant_bwht_bwd(bits, max_block, tau, x, g):
    m = jnp.asarray(walsh_mod.bwht_matrix(x.shape[-1], max_block), x.dtype)
    q, scale = ref.quantize_ref(x, bits)
    planes = ref.bitplanes_ref(q, bits)  # (bits, ..., n)
    psum = planes @ m.T
    # Normalized PSUM so tau operates on an O(1) operand regardless of n.
    n = x.shape[-1]
    sg = sign_approx_grad(psum / n, tau) / n  # (bits, ..., n)
    w = (2.0 ** jnp.arange(bits, dtype=x.dtype)).reshape(
        (bits,) + (1,) * x.ndim
    )
    # dL/dpsum_b = g * 2^(b-1) * sign'(psum_b); chain through B: @ m.
    dplane = (g[None] * w * sg) @ m  # (bits, ..., n)
    # Straight-through across the bitplane quantizer: plane b contributes
    # 2^(b-1)/ (2^bits - 1) of x/scale; summing the weighted planes
    # recovers a unit pass-through (then the final *scale cancels 1/scale).
    wsum = float(2**bits - 1)
    dx = jnp.sum(dplane * w, axis=0) / wsum
    return (dx * scale / jnp.maximum(scale, 1e-8),)


quant_bwht_ste.defvjp(_quant_bwht_fwd, _quant_bwht_bwd)


def quant_bwht_soft(
    x: jnp.ndarray, bits: int, max_block: int, tau: float
) -> jnp.ndarray:
    """Fully-smooth version of Eq. (4) (used early in tau annealing).

    Replaces sign() with tanh(tau .) on the normalized PSUM.  Keeps the
    exact bitplane decomposition (it is piecewise-constant but the STE
    above handles it; for the soft forward we simply reuse the hard
    planes — the smoothness that matters for loss geometry is the
    comparator's).
    """
    m = jnp.asarray(walsh_mod.bwht_matrix(x.shape[-1], max_block), x.dtype)
    q, scale = ref.quantize_ref(x, bits)
    planes = ref.bitplanes_ref(q, bits)
    n = x.shape[-1]
    psum = planes @ m.T
    obits = sign_approx(psum / n, tau)
    w = (2.0 ** jnp.arange(bits, dtype=x.dtype)).reshape(
        (bits,) + (1,) * x.ndim
    )
    return jnp.sum(obits * w, axis=0) * scale

"""Training loops (build-time only — rust never imports this).

Implements the paper's training methodology (Sec. III-B/C):
  * float baseline training,
  * quantization-aware training (QAT) against the exact Eq. 4 forward with
    surrogate gradients and tau annealing (Fig. 8),
  * early-termination training with the Eq. 8 Wald regularizer (Fig. 9a).

Hand-rolled Adam (no optax on the box).  Models are the DESIGN.md §1
substitutes: same structure as the paper's ResNet20/MobileNetV2 edits,
synthetic data, a few hundred steps.
"""

from __future__ import annotations

import functools
import json
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile import losses, model, surrogate

# --------------------------------------------------------------------------
# Hand-rolled Adam
# --------------------------------------------------------------------------


def adam_init(params):
    zeros = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p
        - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Generic trainer
# --------------------------------------------------------------------------


def make_loss_fn(
    forward: Callable, stat, mode: str, bits: int, lam: float, t_max: float
):
    """Loss over trainable arrays (static config closed over)."""

    def loss_fn(arrs, x, y, tau):
        params = model.merge_params(arrs, stat)
        logits = forward(params, x, mode=mode, bits=bits, tau=tau)
        ts = model.collect_thresholds(params)
        return losses.et_regularized_loss(logits, y, ts, lam=lam, t_max=t_max)

    return loss_fn


def train(
    forward: Callable,
    params: model.Params,
    xtr: np.ndarray,
    ytr: np.ndarray,
    xte: np.ndarray,
    yte: np.ndarray,
    mode: str = "float",
    bits: int = 8,
    lam: float = 0.0,
    t_max: float = 1.0,
    steps: int = 300,
    batch: int = 64,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 50,
    tau_min: float = 2.0,
    tau_max: float = 24.0,
) -> tuple[model.Params, dict]:
    """Run SGD; returns (trained params, history dict)."""
    arrs, stat = model.split_params(params)
    loss_fn = make_loss_fn(forward, stat, mode, bits, lam, t_max)
    # tau is static (the STE custom_vjp takes it as a nondiff python float);
    # annealing would recompile per step, so tau is quantized to 8 levels
    # below and jit caches one executable per level.
    grad_fn = jax.jit(jax.value_and_grad(loss_fn), static_argnums=(3,))

    opt = adam_init(arrs)
    rng = np.random.RandomState(seed)
    hist = {"step": [], "loss": [], "test_acc": [], "tau": []}

    @functools.lru_cache(maxsize=8)
    def _eval_fn(tau):
        def f(arrs_, x, y):
            p = model.merge_params(arrs_, stat)
            logits = forward(p, x, mode=mode, bits=bits, tau=tau)
            return losses.accuracy(logits, y)

        return jax.jit(f)

    n = len(xtr)
    for step in range(steps):
        tau_raw = surrogate.tau_schedule(step, steps, tau_min, tau_max)
        # Quantize tau to 8 annealing levels to bound recompiles.
        levels = np.geomspace(tau_min, tau_max, 8)
        tau = float(levels[np.argmin(np.abs(levels - tau_raw))])
        idx = rng.randint(0, n, size=batch)
        x = jnp.asarray(xtr[idx])
        y = jnp.asarray(ytr[idx])
        loss, grads = grad_fn(arrs, x, y, tau)
        arrs, opt = adam_update(arrs, grads, opt, lr=lr)
        if step % log_every == 0 or step == steps - 1:
            acc = float(
                _eval_fn(tau)(arrs, jnp.asarray(xte), jnp.asarray(yte))
            )
            hist["step"].append(step)
            hist["loss"].append(float(loss))
            hist["test_acc"].append(acc)
            hist["tau"].append(tau)
    return model.merge_params(arrs, stat), hist


def evaluate(
    forward: Callable,
    params: model.Params,
    x: np.ndarray,
    y: np.ndarray,
    mode: str,
    bits: int = 8,
    tau: float = 24.0,
    batch: int = 256,
) -> float:
    accs = []
    for i in range(0, len(x), batch):
        logits = forward(
            params, jnp.asarray(x[i : i + batch]), mode=mode, bits=bits, tau=tau
        )
        accs.append(
            float(losses.accuracy(logits, jnp.asarray(y[i : i + batch])))
            * len(x[i : i + batch])
        )
    return sum(accs) / len(x)


# --------------------------------------------------------------------------
# Weight export for the rust inference engine
# --------------------------------------------------------------------------


def export_weights(params: model.Params, path: str) -> None:
    """Flat JSON export: {name: {shape, data(row-major floats)}}.

    Rust's nn::loader reads this; JSON keeps the loader dependency-free
    (sizes here are tiny — thresholds and small conv stacks).
    """
    flat: dict[str, dict] = {}

    def walk(node, prefix):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{prefix}.{k}" if prefix else k)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(v, f"{prefix}[{i}]")
        elif hasattr(node, "shape"):
            arr = np.asarray(node, dtype=np.float32)
            flat[prefix] = {
                "shape": list(arr.shape),
                "data": [float(v) for v in arr.reshape(-1)],
            }
        else:
            flat[prefix] = {"static": node}

    walk(params, "")
    with open(path, "w") as f:
        json.dump(flat, f)


def mlp_dataset():
    x, y = data_mod.make_vector_dataset()
    return data_mod.train_test_split(x, y)


def image_dataset():
    x, y = data_mod.make_image_dataset()
    return data_mod.train_test_split(x, y)

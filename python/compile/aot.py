"""AOT lowering: jax/pallas -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE at build time (`make artifacts`); the rust binary is
self-contained afterwards.  Artifact set (shapes in manifest.json):

  wht16             pallas WHT kernel, one 16-wide Walsh block
  quant_bwht64      Eq. 4 ADC-free quantized transform (pallas, 8-bit)
  bwht_layer64      fused transform->S_T->inverse layer (pallas)
  mlp_fwd           float MLP forward (params..., x) -> logits
  mlp_fwd_qat       hardware-arithmetic MLP forward (Eq. 4 path)
  train_step        one fused fwd+bwd+SGD step -> (params'..., loss)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import losses, model
from compile.kernels import bitplane, bwht

TAU_AOT = 24.0  # fixed (final) annealing temperature baked into train_step
BITS_AOT = 8
SGD_LR = 0.02


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides the
    # baked Walsh matrices as literal "{...}", which the rust-side text
    # parser silently reads back as zeros.
    return comp.as_hlo_text(True)


# --------------------------------------------------------------------------
# Artifact functions.  Flat positional params (the xla crate executes with
# a positional &[Literal] — manifest.json documents the order).
# --------------------------------------------------------------------------

MLP_ARGS = ("fc1_w", "fc1_b", "bwht_t", "fc2_w", "fc2_b")


def _pack_mlp(w1, b1, t, w2, b2) -> model.Params:
    return {"fc1": {"w": w1, "b": b1}, "bwht": {"t": t}, "fc2": {"w": w2, "b": b2}}


def mlp_fwd(w1, b1, t, w2, b2, x):
    return (model.mlp_forward(_pack_mlp(w1, b1, t, w2, b2), x, mode="float"),)


def mlp_fwd_qat(w1, b1, t, w2, b2, x):
    return (
        model.mlp_forward(
            _pack_mlp(w1, b1, t, w2, b2), x, mode="qat", bits=BITS_AOT, tau=TAU_AOT
        ),
    )


def train_step(w1, b1, t, w2, b2, x, y):
    """One SGD step with the QAT forward; returns (params..., loss)."""

    def loss_fn(flat):
        p = _pack_mlp(*flat)
        logits = model.mlp_forward(
            p, x, mode="qat", bits=BITS_AOT, tau=TAU_AOT
        )
        ts = model.collect_thresholds(p)
        return losses.et_regularized_loss(logits, y, ts, lam=1e-4, t_max=1.0)

    flat = (w1, b1, t, w2, b2)
    loss, grads = jax.value_and_grad(loss_fn)(flat)
    new = tuple(p - SGD_LR * g for p, g in zip(flat, grads))
    return (*new, loss)


def wht16(x):
    return (bwht.wht_pallas(x),)


def quant_bwht64(x):
    return (bitplane.quant_bwht_pallas(x, bits=BITS_AOT),)


def bwht_layer64(x, t):
    return (bwht.bwht_layer_pallas(x, t),)


# --------------------------------------------------------------------------


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_artifacts(out_dir: str, batch: int = 64) -> dict:
    din, hidden, classes = 64, 64, 10
    specs = {
        "wht16": (wht16, [("x", f32(16, 16))]),
        "quant_bwht64": (quant_bwht64, [("x", f32(32, 64))]),
        "bwht_layer64": (bwht_layer64, [("x", f32(32, 64)), ("t", f32(64))]),
        "mlp_fwd": (
            mlp_fwd,
            [
                ("fc1_w", f32(din, hidden)),
                ("fc1_b", f32(hidden)),
                ("bwht_t", f32(hidden)),
                ("fc2_w", f32(hidden, classes)),
                ("fc2_b", f32(classes)),
                ("x", f32(batch, din)),
            ],
        ),
        "mlp_fwd_qat": (
            mlp_fwd_qat,
            [
                ("fc1_w", f32(din, hidden)),
                ("fc1_b", f32(hidden)),
                ("bwht_t", f32(hidden)),
                ("fc2_w", f32(hidden, classes)),
                ("fc2_b", f32(classes)),
                ("x", f32(batch, din)),
            ],
        ),
        "train_step": (
            train_step,
            [
                ("fc1_w", f32(din, hidden)),
                ("fc1_b", f32(hidden)),
                ("bwht_t", f32(hidden)),
                ("fc2_w", f32(hidden, classes)),
                ("fc2_b", f32(classes)),
                ("x", f32(batch, din)),
                ("y", i32(batch)),
            ],
        ),
    }
    manifest = {"tau": TAU_AOT, "bits": BITS_AOT, "sgd_lr": SGD_LR, "artifacts": {}}
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, args) in specs.items():
        arg_specs = [s for _, s in args]
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {
                    "name": n,
                    "shape": list(s.shape),
                    "dtype": str(np.dtype(s.dtype)),
                }
                for n, s in args
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def export_dataset(out_dir: str) -> None:
    """Dump the E2E training dataset + init params for the rust driver."""
    from compile import data as data_mod

    (xtr, ytr), (xte, yte) = (
        lambda d: (d[0], d[1])
    )(data_mod.train_test_split(*data_mod.make_vector_dataset()))
    np.save(os.path.join(out_dir, "train_x.npy"), xtr)
    np.save(os.path.join(out_dir, "train_y.npy"), ytr)
    np.save(os.path.join(out_dir, "test_x.npy"), xte)
    np.save(os.path.join(out_dir, "test_y.npy"), yte)
    p = model.init_mlp(0)
    flat = {
        "fc1_w": p["fc1"]["w"],
        "fc1_b": p["fc1"]["b"],
        "bwht_t": p["bwht"]["t"],
        "fc2_w": p["fc2"]["w"],
        "fc2_b": p["fc2"]["b"],
    }
    for k, v in flat.items():
        np.save(os.path.join(out_dir, f"init_{k}.npy"), np.asarray(v))
    print(f"wrote dataset + init params to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir")
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()
    out_dir = args.out if os.path.isabs(args.out) else os.path.abspath(args.out)
    build_artifacts(out_dir, args.batch)
    export_dataset(out_dir)


if __name__ == "__main__":
    main()

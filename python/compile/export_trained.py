"""`make weights`: train the artifact models and export weights for rust.

Produces (in artifacts/):
  mlp_float.json   float-trained MLP weights
  mlp_qat.json     QAT-trained (Eq. 4 forward) MLP weights
  mlp_et.json      QAT + Eq. 8 early-termination-regularized weights
  train_hist.json  loss/accuracy histories for all three runs

Build-time only; rust's nn::loader consumes the JSON.
"""

from __future__ import annotations

import argparse
import json
import os

from compile import model, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    (xtr, ytr), (xte, yte) = train.mlp_dataset()
    hists = {}

    runs = {
        "mlp_float": dict(mode="float", lam=0.0),
        "mlp_qat": dict(mode="qat", bits=8, lam=0.0),
        "mlp_et": dict(mode="qat", bits=8, lam=0.05, t_max=1.0),
    }
    for name, kw in runs.items():
        p, hist = train.train(
            model.mlp_forward, model.init_mlp(0), xtr, ytr, xte, yte,
            steps=args.steps, **kw,
        )
        train.export_weights(p, os.path.join(out, f"{name}.json"))
        hists[name] = hist
        print(f"{name}: final test acc {hist['test_acc'][-1]:.3f}")

    with open(os.path.join(out, "train_hist.json"), "w") as f:
        json.dump(hists, f, indent=2)


if __name__ == "__main__":
    main()

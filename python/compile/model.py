"""Layer-2 JAX models: BWHT-compressed networks (Figs 2, 3).

Functional, pytree-parameterized models.  Three execution modes for every
BWHT layer, selected by ``mode``:

  * "float" — exact float BWHT (transform -> S_T -> inverse); the paper's
    algorithmic baseline (Fig 1b),
  * "qat"   — exact hardware arithmetic (Eq. 4) on the forward pass with
    surrogate gradients (Eqs. 6-7 via STE) on the backward — what the
    paper trains against so the deployed crossbar sees no train/test skew,
  * "soft"  — fully smoothed forward (tanh comparator) for early-phase
    tau annealing.

Architecture mirrors the paper's Fig. 3: BWHT layers replace the 1x1
convolutions of residual (ResNet20-style) and inverted-bottleneck
(MobileNetV2-style) blocks; a ``freq_layers`` knob converts the first k
1x1 convs to BWHT, reproducing the Fig. 1b sweep.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from compile import surrogate, walsh as walsh_mod
from compile.kernels import ref

Params = dict[str, Any]

# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def _he(rng, shape):
    fan_in = int(np.prod(shape[:-1]))
    return (rng.randn(*shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def init_dense(rng, din: int, dout: int) -> Params:
    return {
        "w": jnp.asarray(_he(rng, (din, dout))),
        "b": jnp.zeros((dout,), jnp.float32),
    }


def init_conv(rng, kh: int, kw: int, cin: int, cout: int) -> Params:
    return {
        "w": jnp.asarray(_he(rng, (kh, kw, cin, cout))),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def init_bwht(rng, dim: int, t_init: float = 0.05, max_block: int = 128) -> Params:
    """A BWHT layer's ONLY trainable parameters: the thresholds T."""
    padded = walsh_mod.bwht_padded_dim(dim, max_block)
    t = np.full((padded,), t_init, dtype=np.float32)
    t += 0.01 * rng.randn(padded).astype(np.float32)
    return {"t": jnp.asarray(t)}


def init_scale_bias(dim: int) -> Params:
    """Lightweight normalization (scale+bias; stats-free for short runs)."""
    return {"g": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


# --------------------------------------------------------------------------
# Primitive layers
# --------------------------------------------------------------------------


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def conv2d(p: Params, x: jnp.ndarray, stride: int = 1, groups: int = 1) -> jnp.ndarray:
    """NHWC conv with SAME padding."""
    return (
        jax.lax.conv_general_dilated(
            x,
            p["w"],
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups,
        )
        + p["b"]
    )


def scale_bias(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x * p["g"] + p["b"]


def _pad_channels(x: jnp.ndarray, dim: int) -> jnp.ndarray:
    cur = x.shape[-1]
    if cur == dim:
        return x
    assert cur < dim
    pad = [(0, 0)] * (x.ndim - 1) + [(0, dim - cur)]
    return jnp.pad(x, pad)


def bwht_core(
    x2d: jnp.ndarray,
    t: jnp.ndarray,
    mode: str,
    bits: int,
    tau: float,
    max_block: int,
) -> jnp.ndarray:
    """Transform -> S_T -> inverse on a (batch, padded_dim) matrix."""
    dim = x2d.shape[-1]
    blocks = walsh_mod.bwht_blocks(dim, max_block)
    assert sum(blocks) == dim
    norm = jnp.concatenate(
        [jnp.full((b,), 1.0 / np.sqrt(float(b)), jnp.float32) for b in blocks]
    )
    if mode == "float":
        fwd = ref.bwht_ref(x2d, max_block) * norm
        thr = ref.soft_threshold_ref(fwd, t)
        return ref.bwht_ref(thr, max_block) * norm
    if mode == "qat":
        fwd = surrogate.quant_bwht_ste(x2d, bits, max_block, tau) * norm
        thr = ref.soft_threshold_ref(fwd, t)
        return surrogate.quant_bwht_ste(thr, bits, max_block, tau) * norm
    if mode == "soft":
        fwd = surrogate.quant_bwht_soft(x2d, bits, max_block, tau) * norm
        thr = ref.soft_threshold_ref(fwd, t)
        return surrogate.quant_bwht_soft(thr, bits, max_block, tau) * norm
    raise ValueError(f"unknown mode {mode!r}")


def bwht_layer(
    p: Params,
    x: jnp.ndarray,
    out_dim: int,
    mode: str = "float",
    bits: int = 8,
    tau: float = 8.0,
    max_block: int = 128,
) -> jnp.ndarray:
    """1D-BWHT channel expansion/projection (Fig. 2).

    x: (..., cin).  Expansion (out_dim > cin): zero-pad channels to the
    padded transform width, transform, threshold, inverse, keep out_dim.
    Projection (out_dim < cin): transform at cin width, threshold, inverse,
    truncate to out_dim (low-sequency channels carry the energy).
    """
    cin = x.shape[-1]
    width = max(cin, out_dim)
    padded = walsh_mod.bwht_padded_dim(width, max_block)
    assert p["t"].shape == (padded,), (p["t"].shape, padded)
    lead = x.shape[:-1]
    x2d = _pad_channels(x, padded).reshape((-1, padded))
    y2d = bwht_core(x2d, p["t"], mode, bits, tau, max_block)
    return y2d.reshape((*lead, padded))[..., :out_dim]


# --------------------------------------------------------------------------
# Blocks (Fig. 3)
# --------------------------------------------------------------------------


def init_residual_block(
    rng, cin: int, cout: int, use_bwht: bool, max_block: int = 128
) -> Params:
    """Residual block: depthwise 3x3 (spatial) + 1x1/BWHT (channel mixing).

    The channel-mixing 1x1 conv carries the bulk of the parameters (cin*cout
    vs 9*cin for the depthwise), matching the regime of the paper's Fig. 3
    where replacing 1x1 convs with parameter-free BWHT yields the ~55%
    model-size reduction of Fig. 1b.
    """
    p: Params = {
        "dw": init_conv(rng, 3, 3, 1, cin),  # depthwise: HWIO with I=1
        "norm1": init_scale_bias(cin),
        "norm2": init_scale_bias(cout),
        "use_bwht": use_bwht,
    }
    if use_bwht:
        p["mix"] = init_bwht(rng, max(cin, cout), max_block=max_block)
    else:
        p["mix"] = init_conv(rng, 1, 1, cin, cout)
    if cin != cout:
        p["skip"] = init_dense(rng, cin, cout)  # 1x1-equivalent skip
    return p


def residual_block(
    p: Params,
    x: jnp.ndarray,
    mode: str,
    bits: int,
    tau: float,
    max_block: int = 128,
) -> jnp.ndarray:
    """ResNet20-style block with the 1x1 conv replaceable by BWHT (Fig 3a)."""
    cin = x.shape[-1]
    h = jax.nn.relu(scale_bias(p["norm1"], conv2d(p["dw"], x, groups=cin)))
    cout = p["norm2"]["g"].shape[0]
    if p["use_bwht"]:
        h = bwht_layer(p["mix"], h, cout, mode, bits, tau, max_block)
    else:
        h = conv2d(p["mix"], h)
    h = scale_bias(p["norm2"], h)
    skip = dense(p["skip"], x) if "skip" in p else x
    return jax.nn.relu(h + skip)


def init_bottleneck_block(
    rng, cin: int, expand: int, cout: int, use_bwht: bool, max_block: int = 128
) -> Params:
    mid = cin * expand
    p: Params = {
        "dw": init_conv(rng, 3, 3, 1, mid),  # depthwise: HWIO with I=1
        "norm": init_scale_bias(mid),
        "use_bwht": use_bwht,
        "mid": mid,
    }
    if use_bwht:
        p["expand"] = init_bwht(rng, max(cin, mid), max_block=max_block)
        p["project"] = init_bwht(rng, max(mid, cout), max_block=max_block)
    else:
        p["expand"] = init_conv(rng, 1, 1, cin, mid)
        p["project"] = init_conv(rng, 1, 1, mid, cout)
    return p


def bottleneck_block(
    p: Params,
    x: jnp.ndarray,
    mode: str,
    bits: int,
    tau: float,
    max_block: int = 128,
) -> jnp.ndarray:
    """MobileNetV2 inverted bottleneck, 1x1 convs -> BWHT (Fig 3b)."""
    mid = p["mid"]
    if p["use_bwht"]:
        h = bwht_layer(p["expand"], x, mid, mode, bits, tau, max_block)
    else:
        h = jax.nn.relu6(conv2d(p["expand"], x))
    h = jax.nn.relu6(scale_bias(p["norm"], conv2d(p["dw"], h, groups=mid)))
    if p["use_bwht"]:
        h = bwht_layer(p["project"], h, x.shape[-1], mode, bits, tau, max_block)
    else:
        h = conv2d(p["project"], h)
    return x + h if h.shape == x.shape else h


# --------------------------------------------------------------------------
# Full models
# --------------------------------------------------------------------------

RESNET_STAGES = ((16, 2), (32, 2), (64, 2))  # (channels, blocks) per stage


def init_bwht_resnet(
    seed: int, freq_layers: int, classes: int = 10, max_block: int = 128
) -> Params:
    """Small ResNet20-style net; the first ``freq_layers`` mixing layers
    (in depth order) use BWHT instead of 1x1 convs (Fig 1b sweep knob)."""
    rng = np.random.RandomState(seed)
    p: Params = {
        "stem": init_conv(rng, 3, 3, 3, 16),
        "blocks": [],
        "freq_layers": freq_layers,
    }
    cin = 16
    idx = 0
    for cout, nblocks in RESNET_STAGES:
        for _ in range(nblocks):
            p["blocks"].append(
                init_residual_block(
                    rng, cin, cout, use_bwht=idx < freq_layers, max_block=max_block
                )
            )
            cin = cout
            idx += 1
    p["head"] = init_dense(rng, cin, classes)
    return p


def num_mixing_layers() -> int:
    return sum(n for _, n in RESNET_STAGES)


def bwht_resnet(
    p: Params,
    x: jnp.ndarray,
    mode: str = "float",
    bits: int = 8,
    tau: float = 8.0,
    max_block: int = 128,
) -> jnp.ndarray:
    h = jax.nn.relu(conv2d(p["stem"], x))
    for i, bp in enumerate(p["blocks"]):
        # Downsample at stage boundaries via stride-2 average pooling.
        if i in (2, 4):
            h = (
                jax.lax.reduce_window(
                    h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                )
                / 4.0
            )
        h = residual_block(bp, h, mode, bits, tau, max_block)
    h = jnp.mean(h, axis=(1, 2))  # GAP
    return dense(p["head"], h)


def init_mlp(seed: int, din: int = 64, hidden: int = 64, classes: int = 10) -> Params:
    """The E2E-training artifact model: dense -> BWHT layer -> dense."""
    rng = np.random.RandomState(seed)
    return {
        "fc1": init_dense(rng, din, hidden),
        "bwht": init_bwht(rng, hidden),
        "fc2": init_dense(rng, hidden, classes),
    }


def mlp_forward(
    p: Params,
    x: jnp.ndarray,
    mode: str = "float",
    bits: int = 8,
    tau: float = 8.0,
    max_block: int = 128,
) -> jnp.ndarray:
    h = jax.nn.relu(dense(p["fc1"], x))
    h = bwht_layer(p["bwht"], h, h.shape[-1], mode, bits, tau, max_block)
    return dense(p["fc2"], h)


def collect_thresholds(p: Params) -> list[jnp.ndarray]:
    """All T vectors in a params tree (for the Eq. 8 regularizer)."""
    ts: list[jnp.ndarray] = []

    def walk(node):
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "t" and isinstance(v, jnp.ndarray):
                    ts.append(v)
                else:
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(p)
    return ts


_STATIC_KEYS = ("use_bwht", "mid", "freq_layers")


def split_params(p: Params):
    """Split a params tree into (trainable arrays, static config).

    jax.grad cannot differentiate through bool/int leaves; training code
    grads over the arrays tree and re-merges the static tree before the
    forward pass (see train.py).
    """

    def walk(node):
        if isinstance(node, dict):
            arrs, stat = {}, {}
            for k, v in node.items():
                if k in _STATIC_KEYS:
                    stat[k] = v
                else:
                    a, s = walk(v)
                    arrs[k] = a
                    if s is not None:
                        stat[k] = s
            return arrs, (stat or None)
        if isinstance(node, list):
            pairs = [walk(v) for v in node]
            arrs = [a for a, _ in pairs]
            stats = [s for _, s in pairs]
            return arrs, (stats if any(s is not None for s in stats) else None)
        return node, None

    return walk(p)


def merge_params(arrs, stat) -> Params:
    """Inverse of split_params."""
    if stat is None:
        return arrs
    if isinstance(arrs, dict):
        out = dict(arrs)
        for k, v in stat.items():
            if k in _STATIC_KEYS:
                out[k] = v
            else:
                out[k] = merge_params(arrs[k], v)
        return out
    if isinstance(arrs, list):
        return [merge_params(a, s) for a, s in zip(arrs, stat)]
    return arrs


def count_params(p: Params) -> int:
    """Trainable parameter count (Fig 1b compression metric)."""
    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            for k, v in node.items():
                if k in ("use_bwht", "mid", "freq_layers"):
                    continue
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
        elif hasattr(node, "shape"):
            total += int(np.prod(node.shape))

    walk(p)
    return total

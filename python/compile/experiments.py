"""Training-based paper figures (build-time python): Figs. 1b, 7, 8, 9a.

Usage: ``cd python && python -m compile.experiments <fig1b|fig7|fig8|fig9a|all>``
Writes CSVs to ../experiments/out/ alongside the rust-side experiments.

These are the experiments that need gradient-based training; everything
else (energy, variability, early-termination statistics) is rust-side
(`cargo run --release --bin experiments`).
"""

from __future__ import annotations

import os
import sys

import numpy as np

from compile import data as data_mod
from compile import losses, model, surrogate, train

OUT = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../experiments/out"))


def write_csv(name: str, header: str, rows: list[str]) -> None:
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"{name}.csv")
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(r + "\n")
    print(f"  -> wrote {path}")


def fig1b(steps: int = 220) -> None:
    """Accuracy & compression vs #frequency-processed layers (BWHT-ResNet).

    Paper: −55.6% params at ~3% accuracy loss on CIFAR10/ResNet20.  Our
    substitute: the DESIGN.md §1 synthetic image set + the small
    bwht_resnet; we report the same two curves.
    """
    print("[fig1b] accuracy & params vs frequency-processed layers")
    x, y = data_mod.make_image_dataset(n=1536)
    (xtr, ytr), (xte, yte) = data_mod.train_test_split(x, y)
    nmix = model.num_mixing_layers()
    rows = []
    base_params = None
    for k in range(nmix + 1):
        p = model.init_bwht_resnet(0, freq_layers=k)
        nparams = model.count_params(p)
        if base_params is None:
            base_params = nparams
        trained, hist = train.train(
            model.bwht_resnet, p, xtr, ytr, xte, yte,
            mode="float", steps=steps, batch=48, lr=2e-3, log_every=steps,
        )
        acc = hist["test_acc"][-1]
        ratio = nparams / base_params
        print(f"  freq_layers={k}/{nmix}: acc {acc:.3f}, params x{ratio:.3f}")
        rows.append(f"{k},{acc:.4f},{ratio:.4f},{nparams}")
    write_csv("fig1b", "freq_layers,test_acc,param_ratio,params", rows)


def fig7() -> None:
    """Surrogate approximation curves (Eqs. 6-7) for several tau."""
    print("[fig7] surrogate approximation functions")
    xs = np.linspace(-2.0, 2.0, 201, dtype=np.float32)
    rows = []
    import jax.numpy as jnp

    for tau in [1.0, 4.0, 16.0, 64.0]:
        ys = np.asarray(surrogate.sign_approx(jnp.asarray(xs), tau))
        rows.extend(f"sign,{tau},{x:.4f},{y:.5f}" for x, y in zip(xs, ys))
    bmax, xmax = 4, 16.0
    xq = np.linspace(0.0, 16.0, 321, dtype=np.float32)
    for tau in [2.0, 8.0, 64.0]:
        # the paper plots the second-most-significant bit (b = bmax-1)
        yb = np.asarray(surrogate.bit_approx(jnp.asarray(xq), bmax - 1, bmax, xmax, tau))
        rows.extend(f"bit,{tau},{x:.4f},{y:.5f}" for x, y in zip(xq, yb))
    write_csv("fig7", "fn,tau,x,y", rows)
    print("  (sign->tanh and bit->sigmoid(sin) staircases sharpen with tau)")


def fig8(steps: int = 260) -> None:
    """Accuracy under 1-bit PSUM quantization vs input bit-width.

    Paper: accuracy converges to a similar level across input quantization
    levels, 3-4% below the float baseline.  We use a noisier variant of
    the vector dataset so the float/QAT gap is visible (the default task
    saturates at 100% for every bit-width).
    """
    print("[fig8] QAT accuracy vs input bits (1-bit PSUM quantization)")
    x, y = data_mod.make_vector_dataset(noise=1.6, seed=1)
    (xtr, ytr), (xte, yte) = data_mod.train_test_split(x, y)
    rows = []
    _, hist_f = train.train(
        model.mlp_forward, model.init_mlp(0), xtr, ytr, xte, yte,
        mode="float", steps=steps, log_every=steps,
    )
    base = hist_f["test_acc"][-1]
    print(f"  float baseline: {base:.3f}")
    rows.append(f"float,{base:.4f}")
    for bits in [1, 2, 4, 6, 8]:
        _, hist = train.train(
            model.mlp_forward, model.init_mlp(0), xtr, ytr, xte, yte,
            mode="qat", bits=bits, steps=steps, log_every=steps,
        )
        acc = hist["test_acc"][-1]
        print(f"  input bits={bits}: acc {acc:.3f} (gap {base - acc:+.3f})")
        rows.append(f"{bits},{acc:.4f}")
    write_csv("fig8", "input_bits,test_acc", rows)


def fig9a(steps: int = 900) -> None:
    """Distribution of trained T with vs without the Eq. 8 regularizer."""
    print("[fig9a] threshold distribution with/without ET regularizer")
    (xtr, ytr), (xte, yte) = train.mlp_dataset()
    rows = []
    for label, lam in [("uniform", 0.0), ("wald", 0.4)]:
        p, hist = train.train(
            model.mlp_forward, model.init_mlp(0), xtr, ytr, xte, yte,
            mode="float", lam=lam, t_max=1.0, steps=steps, log_every=steps,
        )
        ts = np.concatenate([np.asarray(t) for t in model.collect_thresholds(p)])
        mean_abs = float(np.mean(np.abs(ts)))
        print(
            f"  lam={lam}: acc {hist['test_acc'][-1]:.3f}, mean|T| {mean_abs:.3f}, "
            f"frac |T|>0.5: {float(np.mean(np.abs(ts) > 0.5)):.2f}"
        )
        rows.extend(f"{label},{t:.5f}" for t in ts)
    write_csv("fig9a", "mode,threshold", rows)
    print("  (paper: regularizer drives T toward ±1)")


def main() -> None:
    arg = sys.argv[1] if len(sys.argv) > 1 else "all"
    figs = {"fig1b": fig1b, "fig7": fig7, "fig8": fig8, "fig9a": fig9a}
    if arg == "all":
        for f in figs.values():
            f()
    elif arg in figs:
        figs[arg]()
    else:
        raise SystemExit(f"unknown figure {arg}; options: {list(figs)} or all")


if __name__ == "__main__":
    main()

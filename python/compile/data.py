"""Synthetic datasets standing in for CIFAR-10 (DESIGN.md §1 substitution).

The paper's algorithmic results (Figs 1b, 8, 9a, 11a, Table I accuracy row)
are trends over CIFAR-10 training runs.  Full CIFAR-10 training is out of
scope for a CPU build box, so we use deterministic synthetic datasets with
the same *structure* — multi-class images whose class signal lives in a mix
of low- and mid-frequency content, so frequency-domain thresholding faces
the same trade-off the paper measures.

Two generators:
  * make_image_dataset — (N, H, W, C) "CIFAR-like" images: per-class random
    smooth templates (low-frequency) + class-specific Walsh patterns
    (mid-frequency) + i.i.d. noise.
  * make_vector_dataset — flat feature vectors for the MLP/E2E-training
    artifacts.

Everything is seeded and reproducible; the rust side regenerates identical
data from the same seed via a documented xorshift-free path (we export
.npy files instead — see export_npy).
"""

from __future__ import annotations

import numpy as np

from compile import walsh as walsh_mod


def _smooth_template(rng: np.random.RandomState, h: int, w: int, c: int):
    """Low-frequency class template: upsampled coarse noise."""
    coarse = rng.randn(max(h // 4, 1), max(w // 4, 1), c)
    t = np.kron(coarse, np.ones((4, 4, 1)))[:h, :w, :]
    return t / (np.abs(t).max() + 1e-8)


def make_image_dataset(
    n: int = 2048,
    h: int = 16,
    w: int = 16,
    c: int = 3,
    classes: int = 10,
    noise: float = 0.35,
    seed: int = 0,
):
    """Deterministic CIFAR-like dataset: returns (x, y) float32/int32.

    Class signal = smooth template + a class-indexed Walsh row stamped
    into the channel-mean (mid-frequency content that survives BWHT but is
    attenuated by aggressive soft-thresholding — reproducing the accuracy
    vs. compression tension of Fig. 1b).
    """
    rng = np.random.RandomState(seed)
    templates = [_smooth_template(rng, h, w, c) for _ in range(classes)]
    k = int(np.log2(walsh_mod.next_pow2(w)))
    wm = walsh_mod.walsh(k).astype(np.float32)
    x = np.empty((n, h, w, c), dtype=np.float32)
    y = rng.randint(0, classes, size=n).astype(np.int32)
    for i in range(n):
        cls = y[i]
        img = templates[cls].copy()
        # Mid-frequency stripe: Walsh row (cls+2) along width, faded rows.
        row = wm[(cls + 2) % wm.shape[0], :w].astype(np.float32)
        fade = np.linspace(1.0, 0.3, h)[:, None]
        img += 0.5 * (fade * row[None, :])[:, :, None]
        img += noise * rng.randn(h, w, c)
        x[i] = img
    return x, y


def make_vector_dataset(
    n: int = 4096,
    dim: int = 64,
    classes: int = 10,
    noise: float = 0.6,
    seed: int = 1,
):
    """Flat-vector dataset for the MLP artifacts: Walsh-structured classes."""
    rng = np.random.RandomState(seed)
    k = int(np.log2(walsh_mod.next_pow2(dim)))
    wm = walsh_mod.walsh(k).astype(np.float32)[:, :dim]
    protos = np.stack(
        [
            wm[(3 * c + 1) % wm.shape[0]] + 0.5 * wm[(5 * c + 2) % wm.shape[0]]
            for c in range(classes)
        ]
    )
    y = rng.randint(0, classes, size=n).astype(np.int32)
    x = protos[y] + noise * rng.randn(n, dim).astype(np.float32)
    return x.astype(np.float32), y


def train_test_split(x, y, test_frac: float = 0.2, seed: int = 7):
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(x))
    cut = int(len(x) * (1.0 - test_frac))
    tr, te = idx[:cut], idx[cut:]
    return (x[tr], y[tr]), (x[te], y[te])


def export_npy(path_prefix: str, x: np.ndarray, y: np.ndarray) -> None:
    """Dump dataset as .npy for the rust side (exact same bytes)."""
    np.save(path_prefix + "_x.npy", x)
    np.save(path_prefix + "_y.npy", y)

//! Predictive early termination walkthrough (paper Sec. III-C, Figs 9-10).
//!
//! ```bash
//! cargo run --release --example early_termination
//! ```
//!
//! Shows (1) one element's PSUM bounds tightening plane by plane, and
//! (2) the Fig. 9(c) statistics: Uniform- vs Wald-distributed thresholds
//! over 10,000 random 8-bit cases, with the energy consequence.

use repro::bitplane::early_term::{
    run_element, sample_threshold, CycleStats, EarlyTerminator, ThresholdDist,
};
use repro::bitplane::{comparator, QuantBwht};
use repro::energy::EnergyModel;
use repro::quant::Quantizer;
use repro::util::rng::Rng;

fn main() {
    // ---- single-element trace (Fig. 9b) ----
    let mut rng = Rng::seed_from_u64(4);
    let x: Vec<f32> = (0..16).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let q = Quantizer::new(8).quantize(&x);
    let eng = QuantBwht::new(16, 128, 8);
    let t_units = 120.0;
    println!("tracing output element 5 with |T| = {t_units} comparator units:");
    let mut et = EarlyTerminator::new(8, t_units);
    let mut plane = vec![0i8; 16];
    let mut planes = q.planes_msb_first();
    let mut p = 0usize;
    while planes.next_into(&mut plane).is_some() {
        let obit = comparator(eng.plane_psums(&plane)[5]);
        let d = et.step(obit);
        let (lb, ub) = et.bounds();
        println!(
            "  plane {p} (obit {obit:+}): running {:>5}, bounds [{lb:>5}, {ub:>5}] -> {d:?}",
            et.running()
        );
        if d != repro::bitplane::early_term::Decision::Continue {
            break;
        }
        p += 1;
    }

    // ---- Fig. 9(c): 10,000 random cases ----
    println!("\n10,000 random 8-bit input/weight cases (16-wide rows):");
    for dist in [ThresholdDist::Uniform, ThresholdDist::Wald] {
        let mut rng = Rng::seed_from_u64(9);
        let mut stats = CycleStats::new(8);
        for _ in 0..10_000 {
            let x: Vec<f32> = (0..16).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
            let row: Vec<i8> = (0..16).map(|_| if rng.coin() { 1 } else { -1 }).collect();
            let q = Quantizer::new(8).quantize(&x);
            let mut plane = vec![0i8; 16];
            let mut planes = q.planes_msb_first();
            let mut obits: Vec<i8> = Vec::with_capacity(8);
            while planes.next_into(&mut plane).is_some() {
                let psum: i64 = plane
                    .iter()
                    .zip(&row)
                    .map(|(&p, &w)| p as i64 * w as i64)
                    .sum();
                obits.push(comparator(psum));
            }
            let t = sample_threshold(&mut rng, dist, 1.0).abs() * 255.0;
            stats.record(&run_element(&obits, 8, t));
        }
        let hist: Vec<String> = stats
            .histogram
            .iter()
            .enumerate()
            .map(|(c, &n)| format!("{}:{:>5}", c + 1, n))
            .collect();
        println!(
            "  {dist:?}: avg {:.2} cycles | histogram {}",
            stats.average_cycles(),
            hist.join(" ")
        );
        let model = EnergyModel::new(16, 0.8);
        println!(
            "    -> {:.0} TOPS/W at this cycle count (paper: 5311 at 1.34 avg)",
            model.tops_per_watt_et(8, stats.average_cycles())
        );
    }
}

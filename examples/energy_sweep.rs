//! Design-space exploration: the paper's Sec. IV-B sweeps.
//!
//! ```bash
//! cargo run --release --example energy_sweep
//! ```
//!
//! Sweeps VDD for 16×16 and 32×32 crossbars and prints the Fig. 11(c)
//! failure trend, the Fig. 11(d) energy-per-op trend, and the Table I
//! headline numbers — the "should I build the bigger macro?" question a
//! deployment would ask this library.

use repro::analog::crossbar::CrossbarConfig;
use repro::analog::variability::measure_failure;
use repro::energy::EnergyModel;
use repro::util::rng::Rng;

fn main() {
    println!("VDD sweep: processing failure (SM = 0.03) and 1-bit MAC energy\n");
    println!(
        "{:>6} | {:>12} {:>12} {:>12} | {:>10} {:>10}",
        "VDD", "fail 16x16", "fail 32x32", "32x32+boost", "aJ 16x16", "aJ 32x32"
    );
    for vdd_mv in (550..=1000).step_by(50) {
        let vdd = vdd_mv as f64 / 1000.0;
        let mut rng = Rng::seed_from_u64(vdd_mv as u64);
        let f16 = measure_failure(&CrossbarConfig::new(16, vdd), 0.03, 60, 5, &mut rng);
        let f32_ = measure_failure(&CrossbarConfig::new(32, vdd), 0.03, 60, 5, &mut rng);
        let f32b = measure_failure(
            &CrossbarConfig::new(32, vdd).with_boost(0.2),
            0.03,
            60,
            5,
            &mut rng,
        );
        let e16 = EnergyModel::new(16, vdd).mac_energy_aj();
        let e32 = EnergyModel::new(32, vdd).mac_energy_aj();
        println!(
            "{vdd:>5.2}V | {:>11.3}% {:>11.3}% {:>11.3}% | {:>10.0} {:>10.0}",
            f16.rate() * 100.0,
            f32_.rate() * 100.0,
            f32b.rate() * 100.0,
            e16,
            e32
        );
    }

    println!("\nHeadline efficiency @ 0.8 V (paper: 1602 / 5311 TOPS/W):");
    let model = EnergyModel::new(16, 0.8);
    println!(
        "  no early termination: {:.0} TOPS/W",
        model.tops_per_watt(8)
    );
    println!(
        "  with early termination (avg 1.34 cycles): {:.0} TOPS/W",
        model.tops_per_watt_et(8, 1.34)
    );
    println!("\nTakeaway (matches Sec. IV-B): the 16x16 macro stays accurate on a");
    println!("single supply down to ~0.65 V while the 32x32 needs the +0.2 V merge");
    println!("boost, and per-op energy is nearly array-size independent.");
}

//! Quickstart: one BWHT transform on the ADC/DAC-free crossbar stack.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the core API: exact transform (substrate), digital golden model
//! of the crossbar arithmetic (Eq. 4), the full analog Monte-Carlo tile,
//! and the coordinator with early termination — and prints the energy
//! model's verdict.

use repro::analog::crossbar::CrossbarConfig;
use repro::bitplane::early_term::{sample_threshold, ThresholdDist};
use repro::bitplane::QuantBwht;
use repro::coordinator::{Coordinator, CoordinatorConfig, TileKind, TransformRequest};
use repro::energy::EnergyModel;
use repro::util::rng::Rng;
use repro::wht;

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|v| v * v).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
    dot / (na * nb).max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let dim = 64usize;
    let bits = 8u32;
    let mut rng = Rng::seed_from_u64(0);
    let x: Vec<f32> = (0..dim).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();

    // 1. The exact float blockwise Walsh-Hadamard transform (substrate).
    let exact = wht::bwht_apply(&x, dim, 16);
    println!("exact BWHT (16-wide blocks): first 4 = {:?}", &exact[..4]);

    // 2. The ADC-free arithmetic the crossbar actually computes (Eq. 4):
    //    bitplane streaming + 1-bit comparators + binary recombination.
    let golden = QuantBwht::new(dim, 16, bits).transform(&x);
    println!(
        "ADC-free digital golden model: cosine vs exact = {:.3}",
        cosine(&golden, &exact)
    );

    // 3. The same transform on analog tiles with process variability.
    let mut analog = Coordinator::new(CoordinatorConfig {
        tile_n: 16,
        bits,
        kind: TileKind::Analog {
            config: CrossbarConfig::new(16, 0.9),
        },
        ..Default::default()
    });
    let y_analog = analog.transform(&TransformRequest {
        x: x.clone(),
        thresholds_units: vec![0.0; dim],
        scale: None,
        deadline: None,
    })?;
    println!(
        "analog tiles @0.9V:            cosine vs golden = {:.3}",
        cosine(&y_analog, &golden)
    );
    analog.shutdown();

    // 4. Early termination with Wald-trained thresholds: fewer cycles,
    //    same post-activation outputs.
    let th: Vec<f64> = (0..dim)
        .map(|_| sample_threshold(&mut rng, ThresholdDist::Wald, 1.0).abs() * 255.0)
        .collect();
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 16,
        bits,
        ..Default::default()
    });
    coord.transform(&TransformRequest {
        x: x.clone(),
        thresholds_units: th,
        scale: None,
        deadline: None,
    })?;
    let m = coord.metrics();
    let model = EnergyModel::new(16, 0.8);
    println!(
        "early termination: avg {:.2} of {} bitplane cycles/element",
        m.average_cycles(),
        bits
    );
    println!(
        "energy model @0.8V: {:.0} TOPS/W without ET, {:.0} TOPS/W at this cycle count",
        model.tops_per_watt(bits),
        m.tops_per_watt(&model)
    );
    coord.shutdown();
    Ok(())
}

//! END-TO-END VALIDATION DRIVER (DESIGN.md §5).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_infer
//! ```
//!
//! Proves the full three-layer stack composes on a real workload:
//!
//! 1. **L2/L1 → artifact**: the QAT train step (jax model + pallas-lowered
//!    Eq. 4 arithmetic + surrogate gradients) was AOT-lowered to HLO text
//!    at build time;
//! 2. **L3 runtime**: this binary loads it via the PJRT C API and trains
//!    the BWHT classifier for several hundred steps on the synthetic
//!    dataset, logging the loss curve — python never runs;
//! 3. **L3 inference**: the trained weights run through (a) the exact
//!    float engine, (b) the ADC-free digital golden model, and (c) the
//!    analog crossbar Monte-Carlo simulator with early termination via
//!    the coordinator — reporting accuracy, avg bitplane cycles, energy
//!    and TOPS/W.  Numbers are recorded in EXPERIMENTS.md.

use std::time::Instant;

use anyhow::Result;

use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use repro::energy::EnergyModel;
use repro::nn::layers::{accuracy, relu, soft_threshold, Dense};
use repro::nn::{Backend, Mlp};
use repro::npy;
use repro::runtime::{HostTensor, Runtime};
use repro::util::rng::Rng;

const STEPS: usize = 300;
const BATCH: usize = 64;

fn main() -> Result<()> {
    let dir = "artifacts";
    let mut rt = Runtime::new(dir)?;
    println!("== L3 runtime: PJRT platform {} ==", rt.platform());

    // ---- load dataset + init params (exported once at build time) ----
    let mut params: Vec<HostTensor> = ["fc1_w", "fc1_b", "bwht_t", "fc2_w", "fc2_b"]
        .iter()
        .map(|n| {
            let a = npy::load_f32(format!("{dir}/init_{n}.npy")).unwrap();
            HostTensor::f32(&a.shape, a.data)
        })
        .collect();
    let xtr = npy::load_f32(format!("{dir}/train_x.npy"))?;
    let ytr = npy::load_i32(format!("{dir}/train_y.npy"))?;
    let xte = npy::load_f32(format!("{dir}/test_x.npy"))?;
    let yte = npy::load_i32(format!("{dir}/test_y.npy"))?;
    let din = xtr.shape[1];

    // ---- train via the AOT train_step artifact ----
    println!("== training {STEPS} steps (QAT forward, surrogate grads) ==");
    let mut rng = Rng::seed_from_u64(0);
    let t0 = Instant::now();
    let mut curve = Vec::new();
    for step in 0..STEPS {
        let mut bx = Vec::with_capacity(BATCH * din);
        let mut by = Vec::with_capacity(BATCH);
        for _ in 0..BATCH {
            let i = rng.int_range(0, xtr.shape[0] as i64 - 1) as usize;
            bx.extend_from_slice(xtr.row(i));
            by.push(ytr.data[i]);
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(&[BATCH, din], bx));
        inputs.push(HostTensor::i32(&[BATCH], by));
        let mut out = rt.run("train_step", &inputs)?;
        let loss = out.pop().unwrap().scalar_f32()?;
        params = out;
        curve.push(loss);
        if step % 25 == 0 || step == STEPS - 1 {
            println!("  step {step:>4}  loss {loss:.4}");
        }
    }
    println!("  trained in {:?} (loss {:.3} -> {:.3})", t0.elapsed(), curve[0], curve[STEPS - 1]);

    // ---- rebuild the model in the rust inference engine ----
    let flat: Vec<Vec<f32>> = params.iter().map(|t| t.as_f32().unwrap().to_vec()).collect();
    let mlp = Mlp::from_flat(
        din, 64, 10,
        flat[0].clone(), flat[1].clone(), flat[2].clone(),
        flat[3].clone(), flat[4].clone(),
    );

    println!("== inference across backends ==");
    let mut r = Rng::seed_from_u64(1);
    let acc_float = mlp.evaluate(&xte.data, &yte.data, Backend::Float, &mut r, 256);
    let acc_quant = mlp.evaluate(&xte.data, &yte.data, Backend::Quantized { bits: 8 }, &mut r, 256);
    println!("  float (with-ADC baseline):   {:.2}%", acc_float * 100.0);
    println!("  ADC-free digital (Eq. 4):    {:.2}%", acc_quant * 100.0);

    // ---- full analog path through the coordinator, with ET ----
    // The BWHT layer runs its two transforms on analog 16x16 tiles at
    // 0.9 V; thresholds convert to comparator units per input batch.
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 16,
        bits: 8,
        kind: repro::coordinator::TileKind::Analog {
            config: repro::analog::crossbar::CrossbarConfig::new(16, 0.9),
        },
        ..Default::default()
    });
    let hidden = 64usize;
    let fc1 = Dense::new(din, hidden, flat[0].clone(), flat[1].clone());
    let fc2 = Dense::new(hidden, 10, flat[3].clone(), flat[4].clone());
    let tvec = &flat[2];
    let norm = 1.0f32 / (16f32).sqrt(); // 16-wide tiles => 4 blocks of 16
    let n_eval = 500.min(yte.data.len());
    let mut logits_all = Vec::with_capacity(n_eval * 10);
    let t1 = Instant::now();
    for i in 0..n_eval {
        let mut h = fc1.forward(xte.row(i), 1);
        relu(&mut h);
        // forward transform on analog tiles, thresholds in units
        let q = repro::quant::Quantizer::new(8).quantize(&h);
        let th_units: Vec<f64> = tvec
            .iter()
            .map(|t| (t.abs() / (norm * q.scale).max(1e-12)) as f64)
            .collect();
        let f1 = coord.transform(&TransformRequest {
            x: h.clone(),
            thresholds_units: th_units,
            scale: None,
            deadline: None,
        })?;
        let mut freq: Vec<f32> = f1.iter().map(|v| v * norm).collect();
        soft_threshold(&mut freq, tvec);
        let f2 = coord.transform(&TransformRequest {
            x: freq,
            thresholds_units: vec![0.0; hidden],
            scale: None,
            deadline: None,
        })?;
        let spatial: Vec<f32> = f2.iter().map(|v| v * norm).collect();
        logits_all.extend(fc2.forward(&spatial[..hidden], 1));
    }
    let analog_time = t1.elapsed();
    let acc_analog = accuracy(&logits_all, &yte.data[..n_eval], 10);
    let m = coord.metrics();
    let model = EnergyModel::new(16, 0.9);
    println!(
        "  analog crossbar + ET @0.9V:  {:.2}% ({n_eval} samples, {:?})",
        acc_analog * 100.0,
        analog_time
    );
    println!("== coordinator metrics (analog path) ==");
    println!("  avg bitplane cycles/element: {:.2} (8 without ET)", m.average_cycles());
    println!(
        "  early-terminated: {:.1}%  |  modelled energy {:.2} nJ  |  {:.0} TOPS/W",
        100.0 * m.cycles.terminated_early as f64 / m.cycles.total_elements as f64,
        m.energy_fj(&model) / 1e6,
        m.tops_per_watt(&model)
    );
    coord.shutdown();

    // ---- ET-regularized weights (Eq. 8, lambda = 0.05): the paper's
    // workload-reduction story.  `make weights` exports mlp_et.json.
    if std::path::Path::new("artifacts/mlp_et.json").exists() {
        println!("== ET-regularized model (Eq. 8) on the same analog path ==");
        let w = repro::nn::loader::Weights::load("artifacts/mlp_et.json")?;
        let mlp_et = Mlp::from_weights(&w)?;
        let mut coord = Coordinator::new(CoordinatorConfig {
            tile_n: 16,
            bits: 8,
            kind: repro::coordinator::TileKind::Analog {
                config: repro::analog::crossbar::CrossbarConfig::new(16, 0.9),
            },
            ..Default::default()
        });
        let tvec_et = &mlp_et.bwht.t;
        let mut logits = Vec::with_capacity(n_eval * 10);
        for i in 0..n_eval {
            let mut h = mlp_et.fc1.forward(xte.row(i), 1);
            relu(&mut h);
            let q = repro::quant::Quantizer::new(8).quantize(&h);
            let th_units: Vec<f64> = tvec_et
                .iter()
                .map(|t| (t.abs() / (norm * q.scale).max(1e-12)) as f64)
                .collect();
            let f1 = coord.transform(&TransformRequest {
                x: h.clone(),
                thresholds_units: th_units,
                scale: None,
                deadline: None,
            })?;
            let mut freq: Vec<f32> = f1.iter().map(|v| v * norm).collect();
            soft_threshold(&mut freq, tvec_et);
            let f2 = coord.transform(&TransformRequest {
                x: freq,
                thresholds_units: vec![0.0; hidden],
                scale: None,
                deadline: None,
            })?;
            let spatial: Vec<f32> = f2.iter().map(|v| v * norm).collect();
            logits.extend(mlp_et.fc2.forward(&spatial[..hidden], 1));
        }
        let acc_et = accuracy(&logits, &yte.data[..n_eval], 10);
        let met = coord.metrics();
        println!(
            "  accuracy {:.2}% | avg cycles {:.2} | early-terminated {:.1}% | {:.0} TOPS/W",
            acc_et * 100.0,
            met.average_cycles(),
            100.0 * met.cycles.terminated_early as f64 / met.cycles.total_elements as f64,
            met.tops_per_watt(&model)
        );
        coord.shutdown();
    }

    println!("== E2E summary ==");
    println!(
        "  loss {:.3} -> {:.3} | float {:.1}% | ADC-free {:.1}% | analog {:.1}%",
        curve[0],
        curve[STEPS - 1],
        acc_float * 100.0,
        acc_quant * 100.0,
        acc_analog * 100.0
    );
    Ok(())
}

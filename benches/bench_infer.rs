//! End-to-end NN inference bench: the per-sample in-process quantized
//! loop vs batched execution on crossbar pools through the
//! [`repro::exec::TransformExecutor`] seam (the ISSUE-3 acceptance
//! comparison on a 256-wide hidden layer, plus the ISSUE-4
//! mixed-partition case: hidden = 300 → blocks `[128, 128, 32, 8, 4]`
//! served via sub-tile masking).
//!
//! The in-process loop walks one sample at a time on one thread; the
//! pooled executor turns the whole activation into a batch of
//! `TransformRequest`s fanned out across the pool's workers, and the
//! sharded executor additionally scatter–gathers each sample's blocks
//! across pools.  A bit-identity gate runs before any timing: on the
//! digital backend all paths must agree exactly.
//!
//! Emits `BENCH_infer.json` (results + speedups) as a machine-readable
//! baseline.

use repro::coordinator::{required_tile, Coordinator, CoordinatorConfig};
use repro::exec::{Pooled, Sharded};
use repro::nn::{Backend, Mlp};
use repro::shard::{ShardSet, ShardSetConfig};
use repro::util::bench::{bench, black_box, header, write_json, BenchResult};
use repro::util::rng::Rng;

fn random_mlp(r: &mut Rng, din: usize, hidden: usize, classes: usize) -> Mlp {
    Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.3),
        vec![0.0; hidden],
        vec![0.05; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.3),
        vec![0.0; classes],
    )
}

fn main() {
    header("infer");
    let mut results: Vec<BenchResult> = Vec::new();

    // A 64 -> 256 -> 10 MLP: the 256-wide BWHT layer partitions into two
    // 128-wide blocks, so the pools run 128x128 tiles.
    let din = 64usize;
    let hidden = 256usize;
    let classes = 10usize;
    let batch = 64usize;
    let bits = 8u32;
    let mut r = Rng::seed_from_u64(7);
    let mlp = random_mlp(&mut r, din, hidden, classes);
    let tile = required_tile(mlp.bwht.transform_blocks()).expect("power-of-two blocks");
    assert_eq!(tile, 128, "256-wide hidden layer -> two 128-wide blocks");
    let xs: Vec<f32> = (0..batch * din)
        .map(|_| r.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let backend = Backend::Quantized { bits };

    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        bits,
        workers: 4,
        ..Default::default()
    });
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            bits,
            workers: 2,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("shard set");

    // Correctness gate before timing: digital pooled/sharded inference
    // must be bit-identical to the in-process quantized backend.
    let golden = mlp.forward(&xs, batch, backend, &mut Rng::seed_from_u64(0));
    {
        let mut executor = Pooled::new(&mut coord);
        let pooled = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("pooled forward");
        assert_eq!(pooled, golden, "pooled logits must be bit-identical");
    }
    {
        let mut executor = Sharded::new(&mut set);
        let sharded = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("sharded forward");
        assert_eq!(sharded, golden, "sharded logits must be bit-identical");
    }

    // 1. The pre-executor baseline: one sample at a time, one thread.
    let mut rng = Rng::seed_from_u64(1);
    let r_inproc = bench(&format!("in-process per-sample batch{batch}"), || {
        for i in 0..batch {
            let y = mlp.forward(&xs[i * din..(i + 1) * din], 1, backend, &mut rng);
            black_box(y);
        }
    });
    r_inproc.report_throughput(batch as f64, "sample");
    results.push(r_inproc.clone());

    // 2. Batched through one 4-worker pool.
    let r_pooled = bench(&format!("pooled batch{batch} tile{tile} workers4"), || {
        let mut executor = Pooled::new(&mut coord);
        let y = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("pooled forward");
        black_box(y);
    });
    r_pooled.report_throughput(batch as f64, "sample");
    results.push(r_pooled.clone());

    // 3. Batched across 2 shards x 2 workers (same hardware budget).
    let r_sharded = bench(&format!("sharded batch{batch} tile{tile} 2x2"), || {
        let mut executor = Sharded::new(&mut set);
        let y = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("sharded forward");
        black_box(y);
    });
    r_sharded.report_throughput(batch as f64, "sample");
    results.push(r_sharded.clone());

    let pooled_speedup = r_inproc.mean.as_secs_f64() / r_pooled.mean.as_secs_f64();
    let sharded_speedup = r_inproc.mean.as_secs_f64() / r_sharded.mean.as_secs_f64();
    println!(
        "batch{batch} hidden{hidden}: pooled speedup {pooled_speedup:.2}x, \
         sharded speedup {sharded_speedup:.2}x over the per-sample loop"
    );

    // 4. The ISSUE-4 mixed-partition case: hidden = 300 partitions as
    // [128, 128, 32, 8, 4], so the 300-wide activation mixes full tiles
    // with sub-tile-masked blocks on the same 128-wide pools.
    let hidden300 = 300usize;
    let mlp300 = random_mlp(&mut r, din, hidden300, classes);
    assert_eq!(
        required_tile(mlp300.bwht.transform_blocks()).expect("power-of-two blocks"),
        tile,
        "300-wide hidden layer reuses the 128-wide pools"
    );
    let xs300: Vec<f32> = (0..batch * din)
        .map(|_| r.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let golden300 = mlp300.forward(&xs300, batch, backend, &mut Rng::seed_from_u64(0));
    {
        let mut executor = Pooled::new(&mut coord);
        let pooled = mlp300
            .forward_with(&mut executor, &xs300, batch, 0)
            .expect("pooled forward (mixed partition)");
        assert_eq!(pooled, golden300, "mixed-partition pooled logits");
    }
    {
        let mut executor = Sharded::new(&mut set);
        let sharded = mlp300
            .forward_with(&mut executor, &xs300, batch, 0)
            .expect("sharded forward (mixed partition)");
        assert_eq!(sharded, golden300, "mixed-partition sharded logits");
    }
    let mut rng300 = Rng::seed_from_u64(2);
    let r_inproc300 = bench(&format!("in-process per-sample batch{batch} hidden300"), || {
        for i in 0..batch {
            let y = mlp300.forward(&xs300[i * din..(i + 1) * din], 1, backend, &mut rng300);
            black_box(y);
        }
    });
    r_inproc300.report_throughput(batch as f64, "sample");
    results.push(r_inproc300.clone());
    let r_pooled300 = bench(&format!("pooled batch{batch} hidden300 mixed-blocks"), || {
        let mut executor = Pooled::new(&mut coord);
        let y = mlp300
            .forward_with(&mut executor, &xs300, batch, 0)
            .expect("pooled forward (mixed partition)");
        black_box(y);
    });
    r_pooled300.report_throughput(batch as f64, "sample");
    results.push(r_pooled300.clone());
    let r_sharded300 = bench(&format!("sharded batch{batch} hidden300 2x2"), || {
        let mut executor = Sharded::new(&mut set);
        let y = mlp300
            .forward_with(&mut executor, &xs300, batch, 0)
            .expect("sharded forward (mixed partition)");
        black_box(y);
    });
    r_sharded300.report_throughput(batch as f64, "sample");
    results.push(r_sharded300.clone());
    let pooled300_speedup = r_inproc300.mean.as_secs_f64() / r_pooled300.mean.as_secs_f64();
    let sharded300_speedup = r_inproc300.mean.as_secs_f64() / r_sharded300.mean.as_secs_f64();
    println!(
        "batch{batch} hidden{hidden300} (mixed partition): pooled speedup \
         {pooled300_speedup:.2}x, sharded speedup {sharded300_speedup:.2}x"
    );

    coord.shutdown();
    set.shutdown();

    let path = "BENCH_infer.json";
    match write_json(
        path,
        "infer",
        &results,
        &[
            ("pooled_batch_speedup", pooled_speedup),
            ("sharded_batch_speedup", sharded_speedup),
            ("pooled_mixed300_speedup", pooled300_speedup),
            ("sharded_mixed300_speedup", sharded300_speedup),
        ],
    ) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

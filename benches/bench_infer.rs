//! End-to-end NN inference bench: the per-sample in-process quantized
//! loop vs batched execution on crossbar pools through the
//! [`repro::exec::TransformExecutor`] seam (the ISSUE-3 acceptance
//! comparison, on a 256-wide hidden layer).
//!
//! The in-process loop walks one sample at a time on one thread; the
//! pooled executor turns the whole activation into a batch of
//! `TransformRequest`s fanned out across the pool's workers, and the
//! sharded executor additionally scatter–gathers each sample's blocks
//! across pools.  A bit-identity gate runs before any timing: on the
//! digital backend all three paths must agree exactly.
//!
//! Emits `BENCH_infer.json` (results + speedups) as a machine-readable
//! baseline.

use repro::coordinator::{Coordinator, CoordinatorConfig};
use repro::exec::{self, Pooled, Sharded};
use repro::nn::{Backend, Mlp};
use repro::shard::{ShardSet, ShardSetConfig};
use repro::util::bench::{bench, black_box, header, write_json, BenchResult};
use repro::util::rng::Rng;

fn main() {
    header("infer");
    let mut results: Vec<BenchResult> = Vec::new();

    // A 64 -> 256 -> 10 MLP: the 256-wide BWHT layer partitions into two
    // 128-wide blocks, so the pools run 128x128 tiles.
    let din = 64usize;
    let hidden = 256usize;
    let classes = 10usize;
    let batch = 64usize;
    let bits = 8u32;
    let mut r = Rng::seed_from_u64(7);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.3),
        vec![0.0; hidden],
        vec![0.05; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.3),
        vec![0.0; classes],
    );
    let tile = exec::uniform_tile(mlp.bwht.transform_blocks()).expect("uniform blocks");
    assert_eq!(tile, 128, "256-wide hidden layer -> two 128-wide blocks");
    let xs: Vec<f32> = (0..batch * din)
        .map(|_| r.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let backend = Backend::Quantized { bits };

    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        bits,
        workers: 4,
        ..Default::default()
    });
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            bits,
            workers: 2,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("shard set");

    // Correctness gate before timing: digital pooled/sharded inference
    // must be bit-identical to the in-process quantized backend.
    let golden = mlp.forward(&xs, batch, backend, &mut Rng::seed_from_u64(0));
    {
        let mut executor = Pooled::new(&mut coord);
        let pooled = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("pooled forward");
        assert_eq!(pooled, golden, "pooled logits must be bit-identical");
    }
    {
        let mut executor = Sharded::new(&mut set);
        let sharded = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("sharded forward");
        assert_eq!(sharded, golden, "sharded logits must be bit-identical");
    }

    // 1. The pre-executor baseline: one sample at a time, one thread.
    let mut rng = Rng::seed_from_u64(1);
    let r_inproc = bench(&format!("in-process per-sample batch{batch}"), || {
        for i in 0..batch {
            let y = mlp.forward(&xs[i * din..(i + 1) * din], 1, backend, &mut rng);
            black_box(y);
        }
    });
    r_inproc.report_throughput(batch as f64, "sample");
    results.push(r_inproc.clone());

    // 2. Batched through one 4-worker pool.
    let r_pooled = bench(&format!("pooled batch{batch} tile{tile} workers4"), || {
        let mut executor = Pooled::new(&mut coord);
        let y = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("pooled forward");
        black_box(y);
    });
    r_pooled.report_throughput(batch as f64, "sample");
    results.push(r_pooled.clone());

    // 3. Batched across 2 shards x 2 workers (same hardware budget).
    let r_sharded = bench(&format!("sharded batch{batch} tile{tile} 2x2"), || {
        let mut executor = Sharded::new(&mut set);
        let y = mlp
            .forward_with(&mut executor, &xs, batch, 0)
            .expect("sharded forward");
        black_box(y);
    });
    r_sharded.report_throughput(batch as f64, "sample");
    results.push(r_sharded.clone());

    let pooled_speedup = r_inproc.mean.as_secs_f64() / r_pooled.mean.as_secs_f64();
    let sharded_speedup = r_inproc.mean.as_secs_f64() / r_sharded.mean.as_secs_f64();
    println!(
        "batch{batch} hidden{hidden}: pooled speedup {pooled_speedup:.2}x, \
         sharded speedup {sharded_speedup:.2}x over the per-sample loop"
    );

    coord.shutdown();
    set.shutdown();

    let path = "BENCH_infer.json";
    match write_json(
        path,
        "infer",
        &results,
        &[
            ("pooled_batch_speedup", pooled_speedup),
            ("sharded_batch_speedup", sharded_speedup),
        ],
    ) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

//! Substrate bench: fast WHT butterfly vs dense Walsh matvec.
//! Regenerates the L3 compute-primitive numbers in EXPERIMENTS.md §Perf.

use repro::util::bench::{bench, black_box, header};
use repro::util::rng::Rng;
use repro::wht;

fn main() {
    header("wht");
    let mut rng = Rng::seed_from_u64(0);
    for k in [4usize, 6, 8, 10] {
        let n = 1 << k;
        let x: Vec<f32> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let mut buf = x.clone();
        bench(&format!("fwht_sequency n={n}"), || {
            buf.copy_from_slice(&x);
            wht::wht_sequency(black_box(&mut buf));
        })
        .report();
        let w = wht::walsh(k);
        bench(&format!("dense_matvec   n={n}"), || {
            black_box(w.matvec(black_box(&x)));
        })
        .report();
    }
    // the bitplane integer path used by tiles
    let xi: Vec<i64> = (0..64).map(|i| (i * 7 % 5) - 2).collect();
    let mut bi = xi.clone();
    bench("fwht_sequency_i64 n=64", || {
        bi.copy_from_slice(&xi);
        wht::fast::wht_sequency_i64(black_box(&mut bi));
    })
    .report();
}

//! Early-termination scheduler bench (Fig. 9c / Table I cycle savings).

use repro::bitplane::early_term::{run_element, sample_threshold, ThresholdDist};
use repro::coordinator::{schedule_transform, Tile, TileKind};
use repro::util::bench::{bench, black_box, header};
use repro::util::rng::Rng;

fn main() {
    header("early_term");
    let mut rng = Rng::seed_from_u64(3);
    let obits: Vec<i8> = (0..8).map(|_| rng.ternary()).collect();
    bench("run_element 8 planes, T=0", || {
        black_box(run_element(black_box(&obits), 8, 0.0));
    })
    .report();
    bench("run_element 8 planes, wald T", || {
        let t = sample_threshold(&mut rng, ThresholdDist::Wald, 1.0).abs() * 255.0;
        black_box(run_element(black_box(&obits), 8, t));
    })
    .report();

    let x: Vec<f32> = (0..16).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let mut tile = Tile::new(16, &TileKind::Digital, 0);
    let zero = vec![0.0f64; 16];
    bench("schedule_transform 16x16 no-ET", || {
        black_box(schedule_transform(&mut tile, black_box(&x), 8, &zero, None));
    })
    .report();
    let wald: Vec<f64> = (0..16)
        .map(|_| sample_threshold(&mut rng, ThresholdDist::Wald, 1.0).abs() * 255.0)
        .collect();
    bench("schedule_transform 16x16 wald-ET", || {
        black_box(schedule_transform(&mut tile, black_box(&x), 8, &wald, None));
    })
    .report();
}

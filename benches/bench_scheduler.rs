//! Bitplane-engine microbenchmark (ISSUE 5): the pre-PR allocation-bound
//! per-sample scheduling loop vs the zero-allocation batch-fused engine
//! ([`repro::coordinator::schedule_batch`]).
//!
//! The baseline is the old `schedule_block` inner loop reproduced
//! verbatim: it materializes the full `Vec<Vec<i8>>` plane stack per
//! request, `collect()`s a fresh readout vector per plane, and burns a
//! branch on terminated rows every plane.  The batched path streams
//! planes through a per-worker [`ScratchArena`] with live-row compaction
//! and hoists quantizer/row-map setup out of the per-sample loop.
//!
//! Grid: widths 16/64/256 × bits 4/8 × early termination off/on, one
//! digital tile, batch of 32 samples.  A bit-identity gate runs before
//! any timing.  Emits `BENCH_scheduler.json` (results + per-config
//! speedups) and **exits non-zero if the headline batched case
//! (256-wide, 8-bit, ET off) is slower than the per-sample baseline** —
//! the CI sanity gate.
//!
//! A second section (ISSUE 6) re-runs the headline config with request
//! tracing in its three states — plain, sampled-out (one dead branch
//! per stage), and actively recording — and emits `BENCH_trace.json`.
//! **Exits non-zero if the sampled-out path costs more than 2% over
//! plain** (min-over-min ratio, robust to scheduler noise): the cost of
//! shipping tracing always-compiled must stay unmeasurable for
//! unsampled requests.
//!
//! A third section (ISSUE 7) prices the fidelity monitor's hot-path
//! bill the same way — one `wants_sample` per drained slice, plus the
//! 1-in-16 winners cloned into a live checker's drop-oldest queue — and
//! emits `BENCH_fidelity.json`.  **Exits non-zero if either the
//! disabled-handle or the monitor-on path costs more than 2% over
//! plain**: shadow verification must never back-pressure serving.
//!
//! A fourth section (PR 8) prices router fusion end to end: a sharded
//! batch of same-partition requests served through the fused
//! multi-sample submit/drain path vs the pre-fusion one-request-per-call
//! dispatch, bit-identity gated before timing, with the pool-job ledger
//! (fused jobs must undercut per-slice jobs).  Emits
//! `BENCH_router.json` and **exits non-zero if the fused path is slower
//! than the per-slice baseline**.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use repro::bitplane::early_term::{Decision, EarlyTerminator};
use repro::coordinator::{
    schedule_batch, CoordinatorConfig, ScratchArena, Tile, TileKind, TilePlan, TransformRequest,
};
use repro::monitor::{Monitor, MonitorConfig, ShadowSample};
use repro::quant::Quantizer;
use repro::shard::{router, ShardSet, ShardSetConfig};
use repro::trace::{self, ExecStats, Stage, TraceConfig, TraceHandle, Tracer};
use repro::util::bench::{bench, black_box, header, write_json, BenchResult};
use repro::util::rng::Rng;

/// The pre-PR `schedule_block` hot loop, kept verbatim as the baseline
/// (per-request plane-stack materialization, per-plane readout
/// collection, per-plane branch on dead rows).
fn legacy_schedule_block(
    tile: &mut Tile,
    x: &[f32],
    bits: u32,
    thresholds_units: &[f64],
    scale: Option<f32>,
    rows: &[usize],
) -> Vec<f32> {
    let n = tile.n();
    let b = x.len();
    let quantizer = Quantizer::new(bits);
    let q = match scale {
        Some(s) => quantizer.quantize_with_scale(x, s),
        None => quantizer.quantize(x),
    };
    if tile.is_digital() && q.q.iter().all(|&v| v == 0) {
        return vec![0.0; b];
    }
    let planes: Vec<Vec<i8>> = (0..bits).rev().map(|p| q.bitplane(p)).collect();
    let mut terminators: Vec<EarlyTerminator> = thresholds_units
        .iter()
        .map(|&t| EarlyTerminator::new(bits, t))
        .collect();
    let mut live: Vec<bool> = vec![true; b];
    let mut done_value: Vec<i64> = vec![0; b];
    let mut padded = vec![0i8; if b < n { n } else { 0 }];
    let identity = b == n && rows.iter().enumerate().all(|(i, &r)| i == r);
    for plane in &planes {
        if !live.iter().any(|&l| l) {
            break;
        }
        let obits = if identity {
            tile.execute_bitplane(plane)
        } else if b == n {
            tile.execute_bitplane_rows(plane, rows)
        } else {
            padded[..b].copy_from_slice(plane);
            tile.execute_bitplane_rows(&padded, rows)
        };
        for i in 0..b {
            if !live[i] {
                continue;
            }
            match terminators[i].step(obits[i]) {
                Decision::Continue => {}
                Decision::TerminateZero => {
                    live[i] = false;
                    done_value[i] = 0;
                }
                Decision::Complete => {
                    live[i] = false;
                    let v = terminators[i].running();
                    done_value[i] = if (v.unsigned_abs() as f64) <= thresholds_units[i] {
                        0
                    } else {
                        v
                    };
                }
            }
        }
    }
    done_value.iter().map(|&v| v as f32 * q.scale).collect()
}

fn main() {
    header("scheduler");
    let batch = 32usize;
    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    for &width in &[16usize, 64, 256] {
        for &bits in &[4u32, 8] {
            for &(et_name, frac) in &[("off", 0.0f64), ("on", 0.5)] {
                let t_units = frac * (((1u32 << bits) - 1) as f64);
                let plan = TilePlan::new(width, &[width]).expect("full-tile plan");
                let rows: Vec<usize> = (0..width).collect();
                let mut r = Rng::seed_from_u64(width as u64 * 31 + bits as u64);
                let reqs: Vec<TransformRequest> = (0..batch)
                    .map(|_| {
                        let x: Vec<f32> = (0..width)
                            .map(|_| r.uniform_range(-1.0, 1.0) as f32)
                            .collect();
                        TransformRequest {
                            thresholds_units: vec![t_units; width],
                            scale: None,
                            deadline: None,
                            x,
                        }
                    })
                    .collect();

                // Bit-identity gate before any timing.
                let mut t_legacy = Tile::new(width, &TileKind::Digital, 0);
                let legacy_out: Vec<Vec<f32>> = reqs
                    .iter()
                    .map(|q| {
                        legacy_schedule_block(
                            &mut t_legacy,
                            &q.x,
                            bits,
                            &q.thresholds_units,
                            q.scale,
                            &rows,
                        )
                    })
                    .collect();
                let mut t_batch = Tile::new(width, &TileKind::Digital, 0);
                let mut arena = ScratchArena::new();
                let gate = schedule_batch(&mut t_batch, &plan, &reqs, bits, &mut arena);
                assert_eq!(
                    gate.values,
                    legacy_out,
                    "bit-identity gate failed: w{width} b{bits} et_{et_name}"
                );
                // Planes actually issued per batch (== legacy's count; the
                // throughput denominator with ET on).
                let planes = gate.planes_issued as f64;

                let r_legacy = bench(&format!("per-sample w{width} b{bits} et_{et_name}"), || {
                    for q in &reqs {
                        let y = legacy_schedule_block(
                            &mut t_legacy,
                            &q.x,
                            bits,
                            &q.thresholds_units,
                            q.scale,
                            &rows,
                        );
                        black_box(y);
                    }
                });
                r_legacy.report_throughput(planes, "plane");
                let r_batch = bench(&format!("batch-fused w{width} b{bits} et_{et_name}"), || {
                    let y = schedule_batch(&mut t_batch, &plan, &reqs, bits, &mut arena);
                    black_box(y);
                });
                r_batch.report_throughput(planes, "plane");

                let speedup = r_legacy.mean.as_secs_f64() / r_batch.mean.as_secs_f64();
                println!("  -> w{width} b{bits} et_{et_name}: batch-fused {speedup:.2}x");
                derived.push((format!("speedup_w{width}_b{bits}_et_{et_name}"), speedup));
                results.push(r_legacy);
                results.push(r_batch);
            }
        }
    }

    let headline = derived
        .iter()
        .find(|(n, _)| n == "speedup_w256_b8_et_off")
        .map(|(_, v)| *v)
        .expect("headline config ran");
    derived.push(("batched_headline_speedup".to_string(), headline));

    let mut derived_refs: Vec<(&str, f64)> = Vec::with_capacity(derived.len());
    for (name, value) in &derived {
        derived_refs.push((name.as_str(), *value));
    }
    let path = "BENCH_scheduler.json";
    match write_json(path, "scheduler", &results, &derived_refs) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // CI sanity gate: the batched engine must never be slower than the
    // per-sample baseline on the headline case.
    if headline < 1.0 {
        eprintln!(
            "FAIL: batch-fused engine is slower than the per-sample baseline \
             (headline speedup {headline:.2}x < 1.0x)"
        );
        std::process::exit(1);
    }
    println!("headline (w256 b8 et_off): {headline:.2}x — gate >= 1.0x passed");

    trace_overhead_gate(batch);
    monitor_overhead_gate(batch);
    router_fusion_gate();
}

/// One request through the router's planned path on its own — the
/// pre-fusion dispatch shape (a 1-sample group splits into per-worker
/// block lanes), used as the per-slice baseline.
fn route_one(set: &mut ShardSet, blocks: &[usize], q: &TransformRequest) -> Vec<f32> {
    let mut out = router::transform_batch_planned(set, blocks, std::slice::from_ref(q))
        .expect("per-slice request");
    out.pop().expect("one request, one output")
}

/// Router fusion, end to end (PR 8): 32 same-partition requests over a
/// 2-shard set, served as ONE fused `transform_batch_planned` call
/// (multi-sample pool jobs) vs one router call per request (single-
/// sample jobs, the pre-fusion dispatch).  Outputs are bit-identity
/// gated against each other before timing, and the pool-job ledger must
/// show fusion spending measurably fewer jobs than sample-slices.  The
/// headline fused speedup is written to `BENCH_router.json` and gated
/// at >= 1.0x.
fn router_fusion_gate() {
    let blocks = [16usize; 6];
    let width: usize = blocks.iter().sum();
    let batch = 32usize;
    let mut r = Rng::seed_from_u64(4096);
    let reqs: Vec<TransformRequest> = (0..batch)
        .map(|_| {
            let x: Vec<f32> = (0..width)
                .map(|_| r.uniform_range(-1.0, 1.0) as f32)
                .collect();
            TransformRequest {
                thresholds_units: vec![0.0; width],
                scale: Some(Quantizer::new(8).scale_for(&x)),
                deadline: None,
                x,
            }
        })
        .collect();

    let mut fused_set = ShardSet::new(ShardSetConfig {
        shards: 2,
        ..Default::default()
    })
    .expect("fused shard set");
    let mut slice_set = ShardSet::new(ShardSetConfig {
        shards: 2,
        ..Default::default()
    })
    .expect("per-slice shard set");

    // Bit-identity gate before any timing, plus the job-count ledger.
    let fused_out = router::transform_batch_planned(&mut fused_set, &blocks, &reqs)
        .expect("fused batch");
    let fused_jobs = fused_set.metrics().jobs;
    let slice_out: Vec<Vec<f32>> = reqs
        .iter()
        .map(|q| route_one(&mut slice_set, &blocks, q))
        .collect();
    let slice_jobs = slice_set.metrics().jobs;
    assert_eq!(fused_out, slice_out, "fusion bit-identity gate failed");
    assert!(
        fused_jobs < slice_jobs,
        "fusion must cut pool jobs: fused {fused_jobs} vs per-slice {slice_jobs}"
    );

    header("router");
    let r_slice = bench("per-slice 2-shard batch-32 w96", || {
        for q in &reqs {
            black_box(route_one(&mut slice_set, &blocks, q));
        }
    });
    r_slice.report_throughput(batch as f64, "req");
    let r_fused = bench("fused 2-shard batch-32 w96", || {
        let y = router::transform_batch_planned(&mut fused_set, &blocks, &reqs);
        black_box(y.expect("fused batch"));
    });
    r_fused.report_throughput(batch as f64, "req");

    let speedup = r_slice.mean.as_secs_f64() / r_fused.mean.as_secs_f64();
    println!(
        "  -> router fusion {speedup:.2}x; {fused_jobs} fused jobs vs {slice_jobs} per-slice"
    );

    let path = "BENCH_router.json";
    match write_json(
        path,
        "router",
        &[r_slice, r_fused],
        &[
            ("router_fused_speedup", speedup),
            ("fused_jobs_per_batch", fused_jobs as f64),
            ("per_slice_jobs_per_batch", slice_jobs as f64),
        ],
    ) {
        Ok(()) => println!("router baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    fused_set.shutdown();
    slice_set.shutdown();

    if speedup < 1.0 {
        eprintln!(
            "FAIL: fused router path is slower than the per-slice dispatch \
             ({speedup:.2}x < 1.0x)"
        );
        std::process::exit(1);
    }
    println!("router fusion {speedup:.2}x — gate >= 1.0x passed");
}

/// Traced-vs-untraced cost of the headline scheduling case.
///
/// A sampled-out request's entire tracing bill is one
/// `TraceHandle::is_active()` branch per pipeline stage — model that
/// faithfully: run the same `schedule_batch` call plus eight dead
/// branches, and demand the minimum observed time stays within 2% of
/// plain.  An actively-recording handle is measured too (real span
/// bookkeeping per batch) but only reported, not gated: sampling is the
/// knob that bounds that cost in production.
fn trace_overhead_gate(batch: usize) {
    let width = 256usize;
    let bits = 8u32;
    let plan = TilePlan::new(width, &[width]).expect("full-tile plan");
    let mut r = Rng::seed_from_u64(width as u64 * 31 + bits as u64);
    let reqs: Vec<TransformRequest> = (0..batch)
        .map(|_| TransformRequest {
            x: (0..width)
                .map(|_| r.uniform_range(-1.0, 1.0) as f32)
                .collect(),
            thresholds_units: vec![0.0; width],
            scale: None,
            deadline: None,
        })
        .collect();
    let mut tile = Tile::new(width, &TileKind::Digital, 0);
    let mut arena = ScratchArena::new();

    header("trace");
    let r_plain = bench("plain w256 b8 et_off", || {
        let y = schedule_batch(&mut tile, &plan, &reqs, bits, &mut arena);
        black_box(y);
    });
    r_plain.report();

    let inactive = TraceHandle::inactive();
    let r_off = bench("traced-off w256 b8 et_off", || {
        let y = schedule_batch(&mut tile, &plan, &reqs, bits, &mut arena);
        for _ in Stage::ALL {
            black_box(inactive.is_active());
        }
        black_box(y);
    });
    r_off.report();

    let tracer = Tracer::new(TraceConfig::default());
    let active = tracer.begin("bench");
    let r_on = bench("traced-on w256 b8 et_off", || {
        let start = trace::now_us();
        let y = schedule_batch(&mut tile, &plan, &reqs, bits, &mut arena);
        active.record_exec(
            start,
            trace::now_us().saturating_sub(start),
            0,
            ExecStats {
                planes: y.planes_issued,
                row_cycles: y.row_cycles,
                elements: y.stats.total_elements,
                terminated_early: y.stats.terminated_early,
            },
        );
        black_box(y);
    });
    r_on.report();
    tracer.finish(active);

    // Min-over-min: both paths' best observed batch is the least noisy
    // comparison a shared CI runner offers.
    let off_overhead = r_off.min.as_secs_f64() / r_plain.min.as_secs_f64() - 1.0;
    let on_overhead = r_on.min.as_secs_f64() / r_plain.min.as_secs_f64() - 1.0;
    println!(
        "  -> traced-off overhead {:.2}% (gate <= 2.00%), traced-on {:.2}% (informational)",
        off_overhead * 100.0,
        on_overhead * 100.0
    );

    let path = "BENCH_trace.json";
    match write_json(
        path,
        "trace",
        &[r_plain, r_off, r_on],
        &[
            ("traced_off_overhead", off_overhead),
            ("traced_on_overhead", on_overhead),
        ],
    ) {
        Ok(()) => println!("trace baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if off_overhead > 0.02 {
        eprintln!(
            "FAIL: sampled-out tracing costs {:.2}% over plain (gate <= 2%)",
            off_overhead * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "traced-off overhead {:.2}% — gate <= 2% passed",
        off_overhead * 100.0
    );
}

/// Fidelity-monitor cost of the headline scheduling case (ISSUE 7).
///
/// Per drained slice the router pays exactly one
/// [`repro::monitor::MonitorHandle::wants_sample`] call; a sampled slice
/// additionally clones its sub-request and observed values into the
/// checker's bounded drop-oldest queue.  Model that bill faithfully:
/// the same `schedule_batch` call plus one `wants_sample` per request —
/// first against a disabled handle (digital-only serving, the default),
/// then against a live monitor with its checker thread running and the
/// 1-in-16 winners enqueued.  Both must stay within 2% of plain
/// (min-over-min): shadow verification never back-pressures serving.
fn monitor_overhead_gate(batch: usize) {
    let width = 256usize;
    let bits = 8u32;
    let plan = TilePlan::new(width, &[width]).expect("full-tile plan");
    let mut r = Rng::seed_from_u64(width as u64 * 31 + bits as u64);
    let reqs: Vec<TransformRequest> = (0..batch)
        .map(|_| TransformRequest {
            x: (0..width)
                .map(|_| r.uniform_range(-1.0, 1.0) as f32)
                .collect(),
            thresholds_units: vec![0.0; width],
            scale: None,
            deadline: None,
        })
        .collect();
    let mut tile = Tile::new(width, &TileKind::Digital, 0);
    let mut arena = ScratchArena::new();

    header("fidelity");
    let r_plain = bench("plain w256 b8 et_off", || {
        let y = schedule_batch(&mut tile, &plan, &reqs, bits, &mut arena);
        black_box(y);
    });
    r_plain.report();

    let disabled = Monitor::disabled();
    let off_handle = disabled.handle();
    let r_off = bench("monitor-off w256 b8 et_off", || {
        let y = schedule_batch(&mut tile, &plan, &reqs, bits, &mut arena);
        for i in 0..batch {
            black_box(off_handle.wants_sample(i));
        }
        black_box(y);
    });
    r_off.report();

    // A live monitor: single eligible slot, real checker thread, golden
    // pool matching the bench geometry.  With the `monitor-off` feature
    // this degenerates to the disabled handle (reported as such).
    let monitor = Monitor::start(
        MonitorConfig {
            sample_every: 16,
            ..MonitorConfig::default()
        },
        CoordinatorConfig {
            tile_n: width,
            bits,
            ..CoordinatorConfig::default()
        },
        vec![true],
        Arc::new(vec![AtomicBool::new(true)]),
    );
    let handle = monitor.handle();
    let on_label = if monitor.is_enabled() {
        "monitor-on (1-in-16) w256 b8 et_off"
    } else {
        "monitor-on (compiled out) w256 b8 et_off"
    };
    let r_on = bench(on_label, || {
        let y = schedule_batch(&mut tile, &plan, &reqs, bits, &mut arena);
        for (i, q) in reqs.iter().enumerate() {
            if handle.wants_sample(0) {
                handle.enqueue(ShadowSample {
                    shard: 0,
                    request: q.clone(),
                    blocks: vec![width],
                    observed: y.values[i].clone(),
                });
            }
        }
        black_box(y);
    });
    r_on.report();

    let off_overhead = r_off.min.as_secs_f64() / r_plain.min.as_secs_f64() - 1.0;
    let on_overhead = r_on.min.as_secs_f64() / r_plain.min.as_secs_f64() - 1.0;
    println!(
        "  -> monitor-off overhead {:.2}%, monitor-on {:.2}% (both gated <= 2.00%); \
         checker saw {} samples ({} dropped)",
        off_overhead * 100.0,
        on_overhead * 100.0,
        monitor.checked_total(),
        monitor.dropped_total()
    );

    let path = "BENCH_fidelity.json";
    match write_json(
        path,
        "fidelity",
        &[r_plain, r_off, r_on],
        &[
            ("monitor_off_overhead", off_overhead),
            ("monitor_on_overhead", on_overhead),
        ],
    ) {
        Ok(()) => println!("fidelity baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if off_overhead > 0.02 || on_overhead > 0.02 {
        eprintln!(
            "FAIL: fidelity monitoring costs {:.2}% (off-handle) / {:.2}% (on) \
             over plain (gate <= 2%)",
            off_overhead * 100.0,
            on_overhead * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "monitor overhead {:.2}% off / {:.2}% on — gate <= 2% passed",
        off_overhead * 100.0,
        on_overhead * 100.0
    );
}

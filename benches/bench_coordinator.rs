//! Coordinator/router bench: request throughput across the worker pool
//! and the sharded-vs-single scatter–gather comparison — the L3 serving
//! claim (EXPERIMENTS.md §Perf).
//!
//! A single pool executes one request on one worker (blocks walked
//! serially on that worker's tile); a shard set splits the same blocks
//! across every pool, so one wide request parallelizes.  Both sides get
//! the same total worker count for a fair comparison.
//!
//! Emits `BENCH_coordinator.json` (results + the wide-request speedup)
//! as a machine-readable baseline.

use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use repro::shard::{router, ShardSet, ShardSetConfig};
use repro::util::bench::{bench, header, write_json, BenchResult};
use repro::util::rng::Rng;

fn main() {
    header("coordinator");
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Rng::seed_from_u64(4);
    for workers in [1usize, 4] {
        for dim in [16usize, 64, 256] {
            let mut coord = Coordinator::new(CoordinatorConfig {
                workers,
                ..Default::default()
            });
            let reqs: Vec<TransformRequest> = (0..32)
                .map(|_| TransformRequest {
                    x: (0..dim).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect(),
                    thresholds_units: vec![0.0; dim],
                    scale: None,
                    deadline: None,
                })
                .collect();
            let r = bench(
                &format!("batch32 dim={dim} workers={workers}"),
                || {
                    coord.transform_batch(&reqs).unwrap();
                },
            );
            r.report_throughput(32.0, "req");
            results.push(r);
            coord.shutdown();
        }
    }

    // Sharded vs single: one 1024-wide request on 16x16 tiles.  Single
    // pool: 4 workers, but a lone request runs on one of them.  Shard
    // set: 4 pools x 1 worker — same hardware, the request fans out.
    let dim = 1024usize;
    let shards = 4usize;
    let req = TransformRequest {
        x: (0..dim).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect(),
        thresholds_units: vec![0.0; dim],
        scale: None,
        deadline: None,
    };

    let mut single = Coordinator::new(CoordinatorConfig {
        workers: shards,
        ..Default::default()
    });
    let mut set = ShardSet::new(ShardSetConfig {
        shards,
        coordinator: CoordinatorConfig {
            workers: 1,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();

    // Correctness gate before timing: the scatter–gather must be
    // bit-identical to the single pool.
    let golden = single.transform(&req).unwrap();
    let sharded_out = router::transform(&mut set, &req).unwrap();
    assert_eq!(sharded_out, golden, "sharded output must be bit-identical");

    let r_single = bench(&format!("wide dim={dim} single-pool"), || {
        single.transform(&req).unwrap();
    });
    r_single.report_throughput(1.0, "req");
    let r_sharded = bench(&format!("wide dim={dim} shards={shards}"), || {
        router::transform(&mut set, &req).unwrap();
    });
    r_sharded.report_throughput(1.0, "req");

    let speedup = r_single.mean.as_secs_f64() / r_sharded.mean.as_secs_f64();
    println!(
        "wide dim={dim}: {shards}-shard scatter-gather speedup over single pool: {speedup:.2}x"
    );
    results.push(r_single);
    results.push(r_sharded);
    single.shutdown();
    set.shutdown();

    let path = "BENCH_coordinator.json";
    match write_json(path, "coordinator", &results, &[("wide1024_shard_speedup", speedup)]) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

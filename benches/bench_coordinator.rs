//! Coordinator/router bench: request throughput across the worker pool —
//! the L3 serving claim (EXPERIMENTS.md §Perf).

use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use repro::util::bench::{bench, header};
use repro::util::rng::Rng;

fn main() {
    header("coordinator");
    let mut rng = Rng::seed_from_u64(4);
    for workers in [1usize, 4] {
        for dim in [16usize, 64, 256] {
            let mut coord = Coordinator::new(CoordinatorConfig {
                workers,
                ..Default::default()
            });
            let reqs: Vec<TransformRequest> = (0..32)
                .map(|_| TransformRequest {
                    x: (0..dim).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect(),
                    thresholds_units: vec![0.0; dim],
                })
                .collect();
            let r = bench(
                &format!("batch32 dim={dim} workers={workers}"),
                || {
                    coord.transform_batch(&reqs).unwrap();
                },
            );
            r.report_throughput(32.0, "req");
            coord.shutdown();
        }
    }
}

//! Analog-simulator bench: full 4-step bitplane op per tile size/backend
//! (Fig. 11 Monte-Carlo cost driver) — per-table target: Table I / Fig 11.

use repro::analog::crossbar::CrossbarConfig;
use repro::analog::variability::{measure_failure, sample_instance};
use repro::util::bench::{bench, black_box, header};
use repro::util::rng::Rng;

fn main() {
    header("crossbar");
    for n in [16usize, 32] {
        let mut rng = Rng::seed_from_u64(1);
        let xb = sample_instance(CrossbarConfig::new(n, 0.9), &mut rng);
        let input: Vec<i8> = (0..n).map(|_| rng.ternary()).collect();
        let r = bench(&format!("analog bitplane op {n}x{n}"), || {
            black_box(xb.execute_bitplane(black_box(&input), &mut rng));
        });
        r.report_throughput((n * n) as f64, "1b-MAC");
        bench(&format!("ideal_psums {n}x{n}"), || {
            black_box(xb.ideal_psums(black_box(&input)));
        })
        .report();
    }
    let mut rng = Rng::seed_from_u64(2);
    bench("fig11b point (16x16, 20 vec x 2 inst)", || {
        black_box(measure_failure(
            &CrossbarConfig::new(16, 0.9),
            0.03,
            20,
            2,
            &mut rng,
        ));
    })
    .report();
}

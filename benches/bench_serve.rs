//! Serving front-end load harness (PR 9): drives the epoll event loop
//! end to end from a second, client-side reactor in the same process.
//!
//! Two phases, both over real TCP against a full `Server`:
//!
//! * **closed-loop** — N concurrent keep-alive connections, each with
//!   exactly one outstanding `/v1/transform` request at a time for R
//!   rounds.  A configurable 1-in-K slice of connections churns: it
//!   sends `Connection: close` on every request and reconnects, so the
//!   accept path and connection teardown stay in the measured loop.
//! * **open-loop** — requests arrive at a fixed rate over a smaller
//!   keep-alive pool regardless of completions; latency is measured
//!   from the *scheduled* arrival, so queueing delay under overload is
//!   visible instead of hidden (closed-loop coordinated omission).
//!
//! Every response is checked for HTTP framing and status 200; every
//! 64th is deep-verified against `QuantBwht::new(16, 16, 8)`.  Emits
//! `BENCH_serve.json` with p50/p99/p99.9 and **exits non-zero if any
//! response is dropped or corrupted**, or if the closed-loop p99
//! regresses more than 10% over the checked-in baseline
//! (`benches/baselines/BENCH_serve.json`) when run at the baseline's
//! connection count — the CI lane runs 512 connections.
//!
//! Knobs (env): `BENCH_SERVE_CONNS` (default 10000), `BENCH_SERVE_ROUNDS`
//! (4), `BENCH_SERVE_CHURN` (8, 0 disables), `BENCH_SERVE_OPEN_RATE`
//! (2000 req/s, 0 skips the phase), `BENCH_SERVE_OPEN_SECS` (2),
//! `BENCH_SERVE_OPEN_POOL` (256), `BENCH_SERVE_REACTORS` (4).  The fd
//! soft limit is raised to fit both ends of every socket; if the hard
//! limit is lower, the connection count clamps to fit.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::os::fd::AsRawFd;
use std::time::{Duration, Instant};

use repro::bitplane::QuantBwht;
use repro::server::reactor::{interest, Epoll, Event};
use repro::server::{AdmissionConfig, Server, ServerConfig};
use repro::util::bench::{header, write_json, BenchResult};
use repro::util::json::{self, Json};
use repro::util::rng::Rng;

// ---------------------------------------------------------------- rlimit

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

const RLIMIT_NOFILE: i32 = 7;

extern "C" {
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
}

/// Raise the fd soft limit toward `want`; returns the resulting cap.
fn raise_nofile(want: u64) -> u64 {
    let mut lim = Rlimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 1024;
    }
    if lim.cur < want {
        let raised = Rlimit {
            cur: want.min(lim.max),
            max: lim.max,
        };
        if unsafe { setrlimit(RLIMIT_NOFILE, &raised) } == 0 {
            return raised.cur;
        }
    }
    lim.cur
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

// -------------------------------------------------------------- payloads

/// One precanned transform request (dim-16, T=0: exact WHT) in both
/// keep-alive and `Connection: close` framings, plus its golden output.
struct Payload {
    keep: Vec<u8>,
    close: Vec<u8>,
    golden: Vec<f32>,
}

fn make_payloads(n: usize) -> Vec<Payload> {
    let mut r = Rng::seed_from_u64(0xbe9c);
    (0..n)
        .map(|_| {
            let x: Vec<f32> = (0..16)
                .map(|_| r.uniform_range(-1.0, 1.0) as f32)
                .collect();
            let vals: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
            let body = format!("{{\"x\":[{}]}}", vals.join(","));
            let keep = format!(
                "POST /v1/transform HTTP/1.1\r\nHost: bench\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .into_bytes();
            let close = format!(
                "POST /v1/transform HTTP/1.1\r\nHost: bench\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
                body.len()
            )
            .into_bytes();
            let golden = QuantBwht::new(16, 16, 8).transform(&x);
            Payload { keep, close, golden }
        })
        .collect()
}

fn is_churn(conn_index: usize, churn_every: usize) -> bool {
    churn_every > 0 && conn_index % churn_every == 0
}

fn request_bytes(payload: &Payload, churn: bool) -> &[u8] {
    if churn {
        &payload.close
    } else {
        &payload.keep
    }
}

// ------------------------------------------------------- response parse

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn parse_status(head: &[u8]) -> Option<u16> {
    let line = head.split(|&b| b == b'\r').next()?;
    let text = std::str::from_utf8(line).ok()?;
    text.split_whitespace().nth(1)?.parse().ok()
}

fn parse_content_length(head: &[u8]) -> Option<usize> {
    let text = std::str::from_utf8(head).ok()?;
    for line in text.split("\r\n").skip(1) {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                return value.trim().parse().ok();
            }
        }
    }
    None
}

fn verify_body(body: &[u8], golden: &[f32]) -> bool {
    let Ok(text) = std::str::from_utf8(body) else {
        return false;
    };
    let Ok(parsed) = json::parse(text) else {
        return false;
    };
    let Some(y) = parsed.get("y").and_then(Json::as_arr) else {
        return false;
    };
    y.len() == golden.len()
        && y.iter()
            .zip(golden)
            .all(|(v, g)| v.as_f64().is_some_and(|f| (f as f32 - g).abs() < 1e-4))
}

// ------------------------------------------------------ client machinery

/// One nonblocking client connection with a single request in flight.
struct ClientConn {
    stream: TcpStream,
    variant: usize,
    sending: bool,
    busy: bool,
    done: bool,
    wbuf: Vec<u8>,
    wpos: usize,
    rbuf: Vec<u8>,
    sent_at: Instant,
    served: u64,
    interest: u32,
}

fn client_connect(addr: SocketAddr) -> io::Result<TcpStream> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_nonblocking(true)?;
    Ok(stream)
}

impl ClientConn {
    fn open(addr: SocketAddr, variant: usize, epoll: &Epoll, token: u64) -> io::Result<ClientConn> {
        let stream = client_connect(addr)?;
        epoll.add(stream.as_raw_fd(), interest::READ, token)?;
        Ok(ClientConn {
            stream,
            variant,
            sending: false,
            busy: false,
            done: false,
            wbuf: Vec::new(),
            wpos: 0,
            rbuf: Vec::new(),
            sent_at: Instant::now(),
            served: 0,
            interest: interest::READ,
        })
    }

    fn set_interest(&mut self, epoll: &Epoll, token: u64, want: u32) -> io::Result<()> {
        if self.interest != want {
            epoll.modify(self.stream.as_raw_fd(), want, token)?;
            self.interest = want;
        }
        Ok(())
    }

    /// Begin one request: queue the bytes, stamp the latency clock at
    /// `at` (the scheduled arrival for open-loop, now for closed-loop),
    /// and flush as much as the socket accepts inline.
    fn start_request(
        &mut self,
        epoll: &Epoll,
        token: u64,
        req: &[u8],
        at: Instant,
    ) -> io::Result<()> {
        self.wbuf.clear();
        self.wbuf.extend_from_slice(req);
        self.wpos = 0;
        self.sending = true;
        self.busy = true;
        self.sent_at = at;
        self.flush(epoll, token)
    }

    /// Push queued request bytes; on completion flip to read interest.
    fn flush(&mut self, epoll: &Epoll, token: u64) -> io::Result<()> {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    return self.set_interest(epoll, token, interest::WRITE);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.sending = false;
        self.set_interest(epoll, token, interest::READ)
    }

    /// Drain the socket into `rbuf`; `Ok(true)` means EOF.
    fn drain(&mut self) -> io::Result<bool> {
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => return Ok(true),
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }

    /// If a complete response is buffered, return `(status, body)` and
    /// consume it.
    fn take_response(&mut self) -> Option<(u16, Vec<u8>)> {
        let head_end = find_subslice(&self.rbuf, b"\r\n\r\n")?;
        let head = &self.rbuf[..head_end];
        let status = parse_status(head)?;
        let clen = parse_content_length(head)?;
        let total = head_end + 4 + clen;
        if self.rbuf.len() < total {
            return None;
        }
        let body = self.rbuf[head_end + 4..total].to_vec();
        self.rbuf.drain(..total);
        Some((status, body))
    }
}

/// Shared per-phase context for the client event loop.
struct Ctx<'a> {
    epoll: &'a Epoll,
    addr: SocketAddr,
    payloads: &'a [Payload],
}

/// Replace a connection's socket with a fresh one (churn / recovery).
fn reopen(ctx: &Ctx, conn: &mut ClientConn, token: u64) -> io::Result<()> {
    let _ = ctx.epoll.delete(conn.stream.as_raw_fd());
    let stream = client_connect(ctx.addr)?;
    ctx.epoll.add(stream.as_raw_fd(), interest::READ, token)?;
    conn.stream = stream;
    conn.interest = interest::READ;
    conn.rbuf.clear();
    Ok(())
}

/// Deregister and shut a finished connection down.
fn retire(epoll: &Epoll, conn: &mut ClientConn) {
    let _ = epoll.delete(conn.stream.as_raw_fd());
    let _ = conn.stream.shutdown(std::net::Shutdown::Both);
    conn.done = true;
    conn.busy = false;
}

#[derive(Default)]
struct LoadStats {
    latencies_us: Vec<u64>,
    completed: u64,
    dropped: u64,
    corrupted: u64,
    elapsed: Duration,
}

/// Book a completed response: latency, status check, sampled deep
/// verification against the payload's golden transform.
fn record(
    conn: &mut ClientConn,
    status: u16,
    body: &[u8],
    payloads: &[Payload],
    verify_every: u64,
    stats: &mut LoadStats,
) {
    conn.served += 1;
    conn.busy = false;
    stats.completed += 1;
    stats
        .latencies_us
        .push(conn.sent_at.elapsed().as_micros() as u64);
    let ok = status == 200
        && (stats.completed % verify_every != 0
            || verify_body(body, &payloads[conn.variant].golden));
    if !ok {
        stats.corrupted += 1;
    }
}

const STALL_LIMIT: Duration = Duration::from_secs(30);

// ------------------------------------------------------------ closed loop

struct ClosedConfig {
    conns: usize,
    rounds: u64,
    churn_every: usize,
    verify_every: u64,
}

enum Step {
    Keep,
    Finished,
}

fn fail_request(ctx: &Ctx, conn: &mut ClientConn, stats: &mut LoadStats) -> Step {
    stats.dropped += 1;
    retire(ctx.epoll, conn);
    Step::Finished
}

fn closed_step(
    ctx: &Ctx,
    conn: &mut ClientConn,
    ev: &Event,
    cfg: &ClosedConfig,
    stats: &mut LoadStats,
) -> Step {
    let churn = is_churn(ev.token as usize, cfg.churn_every);
    if ev.error {
        return fail_request(ctx, conn, stats);
    }
    if conn.sending {
        if ev.writable && conn.flush(ctx.epoll, ev.token).is_err() {
            return fail_request(ctx, conn, stats);
        }
        if conn.sending {
            return Step::Keep;
        }
    }
    if !(ev.readable || ev.rdhup) {
        return Step::Keep;
    }
    let eof = match conn.drain() {
        Ok(eof) => eof,
        Err(_) => return fail_request(ctx, conn, stats),
    };
    if let Some((status, body)) = conn.take_response() {
        record(conn, status, &body, ctx.payloads, cfg.verify_every, stats);
        if conn.served >= cfg.rounds {
            retire(ctx.epoll, conn);
            return Step::Finished;
        }
        if churn && reopen(ctx, conn, ev.token).is_err() {
            return fail_request(ctx, conn, stats);
        }
        let req = request_bytes(&ctx.payloads[conn.variant], churn);
        if conn.start_request(ctx.epoll, ev.token, req, Instant::now()).is_err() {
            return fail_request(ctx, conn, stats);
        }
        return Step::Keep;
    }
    if eof {
        // The server hung up with a request outstanding.
        return fail_request(ctx, conn, stats);
    }
    Step::Keep
}

fn closed_loop(addr: SocketAddr, payloads: &[Payload], cfg: &ClosedConfig) -> LoadStats {
    let epoll = Epoll::new().expect("client epoll");
    let ctx = Ctx {
        epoll: &epoll,
        addr,
        payloads,
    };
    let mut stats = LoadStats::default();
    let start = Instant::now();

    let mut conns: Vec<ClientConn> = Vec::with_capacity(cfg.conns);
    for i in 0..cfg.conns {
        // Pace the connect storm so the listener backlog never overflows
        // into SYN-retransmit stalls.
        if i % 256 == 255 {
            std::thread::sleep(Duration::from_millis(2));
        }
        let token = i as u64;
        let mut conn =
            ClientConn::open(addr, i % payloads.len(), &epoll, token).expect("client connect");
        let churn = is_churn(i, cfg.churn_every);
        let req = request_bytes(&payloads[conn.variant], churn);
        conn.start_request(&epoll, token, req, Instant::now())
            .expect("first request");
        conns.push(conn);
    }

    let mut events = Vec::new();
    let mut active = cfg.conns;
    let mut last_completed = 0u64;
    let mut last_progress = Instant::now();
    while active > 0 {
        epoll
            .wait(&mut events, Some(Duration::from_millis(100)))
            .expect("epoll wait");
        for ev in &events {
            let conn = &mut conns[ev.token as usize];
            if conn.done {
                continue;
            }
            if matches!(closed_step(&ctx, conn, ev, cfg, &mut stats), Step::Finished) {
                active -= 1;
            }
        }
        if stats.completed > last_completed {
            last_completed = stats.completed;
            last_progress = Instant::now();
        } else if last_progress.elapsed() > STALL_LIMIT {
            eprintln!("closed-loop stalled: abandoning {active} connections");
            stats.dropped += active as u64;
            break;
        }
    }
    stats.elapsed = start.elapsed();
    stats
}

// -------------------------------------------------------------- open loop

struct OpenConfig {
    pool: usize,
    rate: f64,
    secs: f64,
    verify_every: u64,
}

fn open_fail(ctx: &Ctx, conn: &mut ClientConn, token: u64, stats: &mut LoadStats) {
    stats.dropped += 1;
    conn.busy = false;
    let _ = reopen(ctx, conn, token);
}

fn open_loop(addr: SocketAddr, payloads: &[Payload], cfg: &OpenConfig) -> LoadStats {
    let epoll = Epoll::new().expect("client epoll");
    let ctx = Ctx {
        epoll: &epoll,
        addr,
        payloads,
    };
    let mut stats = LoadStats::default();
    let mut conns: Vec<ClientConn> = (0..cfg.pool)
        .map(|i| {
            ClientConn::open(addr, i % payloads.len(), &epoll, i as u64).expect("client connect")
        })
        .collect();
    let mut idle: Vec<usize> = (0..cfg.pool).collect();

    let total = (cfg.rate * cfg.secs).round().max(1.0) as u64;
    let period = Duration::from_secs_f64(1.0 / cfg.rate);
    let start = Instant::now();
    let mut next_arrival = start;
    let mut issued = 0u64;
    let mut finished = 0u64;
    let mut queue: VecDeque<Instant> = VecDeque::new();
    let mut events = Vec::new();
    let mut last_finished = 0u64;
    let mut last_progress = Instant::now();

    while finished < total {
        let now = Instant::now();
        while issued < total && now >= next_arrival {
            queue.push_back(next_arrival);
            next_arrival += period;
            issued += 1;
        }
        while !queue.is_empty() {
            let Some(slot) = idle.pop() else { break };
            let at = queue.pop_front().expect("nonempty queue");
            let token = slot as u64;
            let conn = &mut conns[slot];
            let req = &payloads[conn.variant].keep;
            if conn.start_request(&epoll, token, req, at).is_err() {
                open_fail(&ctx, conn, token, &mut stats);
                finished += 1;
                idle.push(slot);
            }
        }
        let timeout = if issued < total {
            next_arrival
                .saturating_duration_since(Instant::now())
                .min(Duration::from_millis(10))
        } else {
            Duration::from_millis(100)
        };
        epoll.wait(&mut events, Some(timeout)).expect("epoll wait");
        for ev in &events {
            let slot = ev.token as usize;
            let conn = &mut conns[slot];
            if !conn.busy {
                // Idle pool member: the server may drop it (idle timer,
                // restart); replace it silently — no request was lost.
                if ev.error || ev.rdhup {
                    let _ = reopen(&ctx, conn, ev.token);
                }
                continue;
            }
            if ev.error {
                open_fail(&ctx, conn, ev.token, &mut stats);
                finished += 1;
                idle.push(slot);
                continue;
            }
            if conn.sending {
                if ev.writable && conn.flush(&epoll, ev.token).is_err() {
                    open_fail(&ctx, conn, ev.token, &mut stats);
                    finished += 1;
                    idle.push(slot);
                    continue;
                }
                if conn.sending {
                    continue;
                }
            }
            if !(ev.readable || ev.rdhup) {
                continue;
            }
            match conn.drain() {
                Err(_) => {
                    open_fail(&ctx, conn, ev.token, &mut stats);
                    finished += 1;
                    idle.push(slot);
                }
                Ok(eof) => {
                    if let Some((status, body)) = conn.take_response() {
                        record(conn, status, &body, payloads, cfg.verify_every, &mut stats);
                        finished += 1;
                        idle.push(slot);
                    } else if eof {
                        open_fail(&ctx, conn, ev.token, &mut stats);
                        finished += 1;
                        idle.push(slot);
                    }
                }
            }
        }
        if finished > last_finished {
            last_finished = finished;
            last_progress = Instant::now();
        } else if last_progress.elapsed() > STALL_LIMIT {
            let lost = total - finished;
            eprintln!("open-loop stalled: abandoning {lost} requests");
            stats.dropped += lost;
            break;
        }
    }
    for conn in &mut conns {
        retire(&epoll, conn);
    }
    stats.elapsed = start.elapsed();
    stats
}

// -------------------------------------------------------------- reporting

fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Summarize a load phase as a `BenchResult`: mean/median/min of the
/// per-request latency distribution, `iters` = completed responses.
fn phase_result(name: &str, stats: &LoadStats) -> BenchResult {
    let lat = &stats.latencies_us;
    let mean_us = if lat.is_empty() {
        0
    } else {
        lat.iter().sum::<u64>() / lat.len() as u64
    };
    BenchResult {
        name: name.to_string(),
        iters: stats.completed,
        mean: Duration::from_micros(mean_us),
        median: Duration::from_micros(pct(lat, 0.5)),
        min: Duration::from_micros(lat.first().copied().unwrap_or(0)),
    }
}

fn main() {
    header("serve");
    let mut conns = env_u64("BENCH_SERVE_CONNS", 10_000) as usize;
    let rounds = env_u64("BENCH_SERVE_ROUNDS", 4).max(1);
    let churn_every = env_u64("BENCH_SERVE_CHURN", 8) as usize;
    let open_rate = env_u64("BENCH_SERVE_OPEN_RATE", 2_000) as f64;
    let open_secs = env_u64("BENCH_SERVE_OPEN_SECS", 2) as f64;
    let open_pool = env_u64("BENCH_SERVE_OPEN_POOL", 256) as usize;
    let reactors = env_u64("BENCH_SERVE_REACTORS", 4) as usize;
    let verify_every = 64u64;

    // Both ends of every socket live in this process.
    let want_fds = (conns + open_pool) as u64 * 2 + 512;
    let got_fds = raise_nofile(want_fds);
    if got_fds < want_fds {
        let usable = (got_fds.saturating_sub(512) / 2).saturating_sub(open_pool as u64) as usize;
        let clamped = conns.min(usable.max(64));
        eprintln!("fd limit {got_fds} < {want_fds}: clamping to {clamped} connections");
        conns = clamped;
    }

    let payloads = make_payloads(64);
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_connections: conns + open_pool + 64,
        reactor_threads: reactors,
        admission: AdmissionConfig {
            max_inflight: 0,
            rate_per_sec: 0.0,
            burst: 32.0,
        },
        keepalive_max_requests: usize::MAX >> 1,
        keepalive_idle: Duration::from_secs(300),
        trace_sample: 0,
        fidelity_sample: 0,
        ..Default::default()
    })
    .expect("server start");
    let addr = server.addr;
    println!(
        "server {addr}: {reactors} reactors; closed-loop {conns} conns x {rounds} rounds \
         (churn 1-in-{churn_every}), open-loop {open_rate:.0} req/s x {open_secs:.0}s \
         over {open_pool} conns"
    );

    let closed_cfg = ClosedConfig {
        conns,
        rounds,
        churn_every,
        verify_every,
    };
    let mut closed = closed_loop(addr, &payloads, &closed_cfg);
    closed.latencies_us.sort_unstable();
    let closed_name = format!("closed-loop {conns}conn x{rounds}");
    let closed_res = phase_result(&closed_name, &closed);
    closed_res.report();
    let closed_rps = closed.completed as f64 / closed.elapsed.as_secs_f64().max(1e-9);
    let closed_p50 = pct(&closed.latencies_us, 0.50) as f64;
    let closed_p99 = pct(&closed.latencies_us, 0.99) as f64;
    let closed_p999 = pct(&closed.latencies_us, 0.999) as f64;
    println!(
        "  -> closed-loop: {} ok in {:.2?} ({closed_rps:.0} req/s), p50 {:.0} us, \
         p99 {:.0} us, p99.9 {:.0} us, {} dropped, {} corrupted",
        closed.completed, closed.elapsed, closed_p50, closed_p99, closed_p999,
        closed.dropped, closed.corrupted
    );

    let open = if open_rate > 0.0 && open_secs > 0.0 {
        let open_cfg = OpenConfig {
            pool: open_pool,
            rate: open_rate,
            secs: open_secs,
            verify_every,
        };
        let mut stats = open_loop(addr, &payloads, &open_cfg);
        stats.latencies_us.sort_unstable();
        Some(stats)
    } else {
        None
    };
    let mut results = vec![closed_res];
    if let Some(stats) = &open {
        let name = format!("open-loop {open_rate:.0}rps x{open_secs:.0}s");
        let res = phase_result(&name, stats);
        res.report();
        println!(
            "  -> open-loop: {} ok, p50 {} us, p99 {} us, p99.9 {} us, \
             {} dropped, {} corrupted",
            stats.completed,
            pct(&stats.latencies_us, 0.50),
            pct(&stats.latencies_us, 0.99),
            pct(&stats.latencies_us, 0.999),
            stats.dropped,
            stats.corrupted
        );
        results.push(res);
    }

    let served = server.shutdown();
    println!("server shut down after {} transform slices", served.requests);

    let empty = LoadStats::default();
    let open_ref = open.as_ref().unwrap_or(&empty);
    let derived: Vec<(&str, f64)> = vec![
        ("connections", conns as f64),
        ("rounds", rounds as f64),
        ("closed_completed", closed.completed as f64),
        ("closed_dropped", closed.dropped as f64),
        ("closed_corrupted", closed.corrupted as f64),
        ("closed_rps", closed_rps),
        ("closed_p50_us", closed_p50),
        ("closed_p99_us", closed_p99),
        ("closed_p999_us", closed_p999),
        ("open_rate_rps", open_rate),
        ("open_completed", open_ref.completed as f64),
        ("open_dropped", open_ref.dropped as f64),
        ("open_corrupted", open_ref.corrupted as f64),
        ("open_p50_us", pct(&open_ref.latencies_us, 0.50) as f64),
        ("open_p99_us", pct(&open_ref.latencies_us, 0.99) as f64),
        ("open_p999_us", pct(&open_ref.latencies_us, 0.999) as f64),
    ];
    let path = "BENCH_serve.json";
    match write_json(path, "serve", &results, &derived) {
        Ok(()) => println!("baseline written to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // Gate 1: a serving front end may never lose or corrupt a response.
    let dropped = closed.dropped + open_ref.dropped;
    let corrupted = closed.corrupted + open_ref.corrupted;
    let mut failed = false;
    if dropped > 0 || corrupted > 0 {
        eprintln!("FAIL: {dropped} dropped / {corrupted} corrupted responses (gate: zero)");
        failed = true;
    } else {
        println!("zero dropped/corrupted responses — gate passed");
    }

    // Gate 2: closed-loop p99 vs the checked-in baseline (only when run
    // at the baseline's connection count — the CI smoke lane's 512).
    let baseline_path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/../benches/baselines/BENCH_serve.json");
    match std::fs::read_to_string(baseline_path).ok().and_then(|t| json::parse(&t).ok()) {
        Some(base) => {
            let base_conns = base.get("connections").and_then(Json::as_f64);
            let base_p99 = base.get("closed_p99_us").and_then(Json::as_f64);
            match (base_conns, base_p99) {
                (Some(bc), Some(bp)) if bc == conns as f64 => {
                    if closed_p99 > bp * 1.10 {
                        eprintln!(
                            "FAIL: closed-loop p99 {closed_p99:.0} us exceeds baseline \
                             {bp:.0} us by more than 10%"
                        );
                        failed = true;
                    } else {
                        println!(
                            "closed-loop p99 {closed_p99:.0} us vs baseline {bp:.0} us \
                             — gate <= +10% passed"
                        );
                    }
                }
                (Some(bc), _) => {
                    println!("baseline is for {bc:.0} connections (run: {conns}); p99 gate skipped");
                }
                _ => println!("baseline lacks closed_p99_us; p99 gate skipped"),
            }
        }
        None => println!("no baseline at {baseline_path}; p99 gate skipped"),
    }

    if failed {
        std::process::exit(1);
    }
}

//! PJRT runtime bench: artifact execution latency (the digital-reference
//! path used by the E2E driver).  Needs `make artifacts`.

use repro::npy;
use repro::runtime::{HostTensor, Runtime};
use repro::util::bench::{bench, black_box, header};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return;
    }
    header("runtime");
    let mut rt = Runtime::new("artifacts").unwrap();
    let params: Vec<HostTensor> = ["fc1_w", "fc1_b", "bwht_t", "fc2_w", "fc2_b"]
        .iter()
        .map(|n| {
            let a = npy::load_f32(format!("artifacts/init_{n}.npy")).unwrap();
            HostTensor::f32(&a.shape, a.data)
        })
        .collect();
    let xtr = npy::load_f32("artifacts/train_x.npy").unwrap();
    let x64 = HostTensor::f32(&[64, 64], xtr.data[..64 * 64].to_vec());
    let y64 = HostTensor::i32(&[64], vec![1; 64]);

    let mut fwd_inputs = params.clone();
    fwd_inputs.push(x64.clone());
    bench("mlp_fwd (batch 64)", || {
        black_box(rt.run("mlp_fwd", &fwd_inputs).unwrap());
    })
    .report();
    bench("mlp_fwd_qat (batch 64, Eq.4 path)", || {
        black_box(rt.run("mlp_fwd_qat", &fwd_inputs).unwrap());
    })
    .report();
    let mut ts_inputs = params.clone();
    ts_inputs.push(x64);
    ts_inputs.push(y64);
    bench("train_step (batch 64, fwd+bwd+sgd)", || {
        black_box(rt.run("train_step", &ts_inputs).unwrap());
    })
    .report();
    let w = HostTensor::f32(&[16, 16], xtr.data[..256].to_vec());
    bench("wht16 pallas kernel artifact", || {
        black_box(rt.run("wht16", std::slice::from_ref(&w)).unwrap());
    })
    .report();
}
